//! Log-bucketed streaming histogram with a bounded *relative* quantile
//! error (DDSketch-style). For accuracy parameter `alpha`, any quantile
//! estimate `m` of a true value `v > 0` satisfies `|m - v| / v <= alpha`:
//! bucket `i` covers `(gamma^(i-1), gamma^i]` with `gamma =
//! (1+alpha)/(1-alpha)`, and the reported mid-point `2*gamma^i/(1+gamma)`
//! is within `alpha` of every value in the bucket.
//!
//! Buckets are sparse (`BTreeMap<i32, u64>`) so memory is proportional to
//! the dynamic range actually observed (~690 buckets span 1..1e6 at the
//! default alpha), and **merge is associative**: merging two histograms
//! adds their bucket counts, so per-lane instruments roll up to cluster
//! totals in any grouping order with the same error bound.

use std::collections::BTreeMap;

/// Default relative accuracy: quantiles within 1%.
pub const DEFAULT_ALPHA: f64 = 0.01;

/// Streaming log-bucketed histogram. Values `<= 0` land in a dedicated
/// zero bucket (latencies and blackouts are non-negative; an exact zero
/// has no log bucket).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    alpha: f64,
    gamma: f64,
    /// `1 / ln(gamma)`, precomputed for the hot record path.
    inv_ln_gamma: f64,
    buckets: BTreeMap<i32, u64>,
    zero_count: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new(DEFAULT_ALPHA)
    }
}

impl LogHistogram {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0, 1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        LogHistogram {
            alpha,
            gamma,
            inv_ln_gamma: 1.0 / gamma.ln(),
            buckets: BTreeMap::new(),
            zero_count: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.sum / self.count as f64)
        }
    }

    pub fn min(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.min)
        }
    }

    pub fn max(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.max)
        }
    }

    /// Record one observation. Non-finite values are ignored (a NaN must
    /// not poison the bucket index).
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v <= 0.0 {
            self.zero_count += 1;
        } else {
            let i = (v.ln() * self.inv_ln_gamma).ceil() as i32;
            *self.buckets.entry(i).or_insert(0) += 1;
        }
    }

    /// Merge `other` into `self` (bucket-count addition: associative and
    /// commutative). Both sides must share an accuracy parameter.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert!(
            (self.alpha - other.alpha).abs() < 1e-12,
            "cannot merge histograms with different alpha ({} vs {})",
            self.alpha,
            other.alpha
        );
        for (&i, &c) in &other.buckets {
            *self.buckets.entry(i).or_insert(0) += c;
        }
        self.zero_count += other.zero_count;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Cumulative bucket view for native Prometheus `histogram` exposition:
    /// `(upper_bound, cumulative_count)` pairs in increasing bound order.
    /// The zero bucket (values `<= 0`) surfaces as bound `0.0` when
    /// occupied; each log bucket `i` reports its exact upper edge
    /// `gamma^i`. The final cumulative count equals [`count`](Self::count),
    /// so the exporter's `+Inf` bucket needs no special casing here.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.buckets.len() + 1);
        let mut cum = 0u64;
        if self.zero_count > 0 {
            cum += self.zero_count;
            out.push((0.0, cum));
        }
        for (&i, &c) in &self.buckets {
            cum += c;
            out.push((self.gamma.powi(i), cum));
        }
        out
    }

    /// Quantile estimate for `q` in `[0, 1]`; `None` on an empty histogram.
    /// The estimate has relative error `<= alpha` against the rank-`q`
    /// recorded value, and is clamped to the observed `[min, max]` so the
    /// extremes are exact.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        // Nearest-rank: the smallest recorded value whose cumulative count
        // reaches ceil(q * count) (rank 1 at q=0 keeps min exact).
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        if rank <= self.zero_count {
            return Some(0.0_f64.max(self.min));
        }
        let mut cum = self.zero_count;
        for (&i, &c) in &self.buckets {
            cum += c;
            if cum >= rank {
                let est = 2.0 * self.gamma.powi(i) / (1.0 + self.gamma);
                return Some(est.clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exact_rank(mut xs: Vec<f64>, q: f64) -> f64 {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q * xs.len() as f64).ceil() as usize).max(1);
        xs[rank - 1]
    }

    #[test]
    fn empty_and_degenerate() {
        let h = LogHistogram::default();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.mean(), None);

        let mut h = LogHistogram::default();
        h.record(42.0);
        assert_eq!(h.quantile(0.0), Some(42.0));
        assert_eq!(h.quantile(0.5), Some(42.0));
        assert_eq!(h.quantile(1.0), Some(42.0));
    }

    #[test]
    fn zero_and_negative_values_hit_the_zero_bucket() {
        let mut h = LogHistogram::default();
        h.record(0.0);
        h.record(-5.0);
        h.record(100.0);
        assert_eq!(h.count(), 3);
        // q=0.5 → rank 2 → still inside the zero bucket (min is -5, so the
        // zero-bucket estimate is clamped up to 0 only when min >= 0).
        assert!(h.quantile(0.5).unwrap() <= 0.0);
        assert_eq!(h.quantile(1.0), Some(100.0));
        h.record(f64::NAN); // ignored
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn quantile_relative_error_is_bounded() {
        // Deterministic LCG over several magnitudes.
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut xs = Vec::new();
        let mut h = LogHistogram::default();
        for _ in 0..5000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let u = (state >> 11) as f64 / (1u64 << 53) as f64;
            let v = 10f64.powf(u * 5.0 - 1.0); // 0.1 .. 10_000
            xs.push(v);
            h.record(v);
        }
        for q in [0.0, 0.01, 0.1, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let exact = exact_rank(xs.clone(), q);
            let est = h.quantile(q).unwrap();
            let rel = (est - exact).abs() / exact;
            assert!(rel <= h.alpha() + 1e-9, "q={q}: est {est} vs exact {exact} (rel {rel})");
        }
        assert!((h.mean().unwrap() - xs.iter().sum::<f64>() / xs.len() as f64).abs() < 1e-9);
    }

    #[test]
    fn merge_is_associative_and_matches_pooled() {
        let mut state = 7u64;
        let mut next = |scale: f64| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 11) as f64 / (1u64 << 53) as f64) * scale + 0.001
        };
        let (mut a, mut b, mut c, mut pooled) = (
            LogHistogram::default(),
            LogHistogram::default(),
            LogHistogram::default(),
            LogHistogram::default(),
        );
        for _ in 0..400 {
            let (x, y, z) = (next(10.0), next(1000.0), next(0.5));
            a.record(x);
            b.record(y);
            c.record(z);
            pooled.record(x);
            pooled.record(y);
            pooled.record(z);
        }
        // (a ⊕ b) ⊕ c  ==  a ⊕ (b ⊕ c)  ==  pooled
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        for h in [&ab_c, &a_bc] {
            assert_eq!(h.count(), pooled.count());
            assert!((h.sum() - pooled.sum()).abs() < 1e-6);
            for q in [0.1, 0.5, 0.9, 0.99] {
                assert_eq!(h.quantile(q), pooled.quantile(q), "q={q}");
            }
        }
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_cover_all_observations() {
        let h = LogHistogram::default();
        assert!(h.cumulative_buckets().is_empty());

        let mut h = LogHistogram::default();
        h.record(0.0); // zero bucket
        for v in [0.5, 3.0, 3.0, 250.0] {
            h.record(v);
        }
        let buckets = h.cumulative_buckets();
        // Zero bucket first, bounds strictly increasing, counts
        // non-decreasing, final count == total count.
        assert_eq!(buckets[0].0, 0.0);
        assert_eq!(buckets[0].1, 1);
        for w in buckets.windows(2) {
            assert!(w[1].0 > w[0].0, "bounds must increase: {buckets:?}");
            assert!(w[1].1 >= w[0].1, "cumulative counts must not drop: {buckets:?}");
        }
        assert_eq!(buckets.last().unwrap().1, h.count());
        // Every recorded positive value is <= the bound of the first bucket
        // whose cumulative count reaches its rank: spot-check the max.
        assert!(buckets.last().unwrap().0 >= 250.0);
    }

    #[test]
    #[should_panic(expected = "different alpha")]
    fn merge_rejects_mismatched_alpha() {
        let mut a = LogHistogram::new(0.01);
        let b = LogHistogram::new(0.02);
        a.merge(&b);
    }
}
