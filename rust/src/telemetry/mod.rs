//! Live telemetry: streaming metrics sampled *during* a run (ISSUE 7).
//!
//! PR 6's `obs` traces answer "what happened" after the fact; this layer is
//! the live half — counters, gauges, per-lane time series and mergeable
//! log-bucketed latency histograms collected at the same
//! [`crate::lane::LaneCore`]/executor choke points, exported as Prometheus
//! text exposition or deterministic CSV, and **consumed by the control
//! plane itself**: [`crate::monitor::Monitor`] stage-rate windows and the
//! cascade [`crate::cascade::ThresholdController`] verdict window are
//! [`RollingWindow`]/[`VerdictWindow`] handles that a [`Registry`] can
//! share, so the signal a controller reacts to is the same object the
//! exporters snapshot.
//!
//! Design constraints (mirroring `obs`):
//!
//! * **Near-zero cost when off.** [`Telemetry`] is a cloneable handle with
//!   an `Option` sink; [`Telemetry::off()`] (the default everywhere) makes
//!   every instrument call a single branch with no allocation — pinned in
//!   `benches/perf_hotpath.rs` next to the trace-emit numbers.
//! * **Deterministic.** Instruments record only simulation-time
//!   quantities; the CSV and Prometheus snapshots of a same-seed run are
//!   byte-identical (BTreeMap key order, no wall-clock values).
//! * **Mergeable.** Per-lane histograms roll up to cluster totals by
//!   associative bucket addition ([`LogHistogram::merge`]), so the
//!   exposition can present both per-lane and cluster quantiles from one
//!   pass of instruments.

pub mod export;
pub mod hist;
pub mod window;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

pub use hist::{LogHistogram, DEFAULT_ALPHA};
pub use window::{RollingWindow, VerdictWindow};

/// Lane stamp for cluster-level instruments (arbiter moves, fault
/// blackouts): same convention as [`crate::obs::CONTROL_LANE`], exported
/// as lane `-1`.
pub use crate::obs::CONTROL_LANE;

/// Default span for rolling windows created implicitly by
/// [`Telemetry::push_window`].
pub const DEFAULT_WINDOW_MS: f64 = 60_000.0;

/// Canonical instrument names. `&'static str` keys keep the off→on path
/// allocation-free and the registry maps deterministically ordered.
pub mod metric {
    /// Requests waiting for dispatch (gauge series, per lane).
    pub const QUEUE_DEPTH: &str = "queue_depth";
    /// Dispatched plan chains in flight (gauge series, per lane).
    pub const INFLIGHT_PLANS: &str = "inflight_plans";
    /// Busy fraction of the lane's GPUs (gauge series, per lane).
    pub const GPU_UTILIZATION: &str = "gpu_utilization";
    /// Device handoff-buffer occupancy, GB (gauge series, per lane).
    pub const HANDOFF_GB: &str = "handoff_gb";
    /// Rolling-window SLO attainment (gauge series sampled from
    /// [`SLO_WINDOW`]).
    pub const SLO_ATTAINMENT: &str = "slo_attainment";
    /// Rolling window of per-completion on-time verdicts (weight 1/0).
    pub const SLO_WINDOW: &str = "slo_window";
    /// End-to-end served latency (log-bucketed histogram, per lane).
    pub const REQUEST_LATENCY_MS: &str = "request_latency_ms";
    /// Streaming latency quantiles (gauge series sampled from the
    /// histogram).
    pub const LATENCY_P50_MS: &str = "latency_p50_ms";
    pub const LATENCY_P95_MS: &str = "latency_p95_ms";
    pub const LATENCY_P99_MS: &str = "latency_p99_ms";
    /// Lifecycle counters, per lane.
    pub const REQUESTS_ARRIVED: &str = "requests_arrived";
    pub const REQUESTS_COMPLETED: &str = "requests_completed";
    pub const REQUESTS_OOM: &str = "requests_oom";
    pub const REQUESTS_DROPPED: &str = "requests_dropped";
    /// Monitor stage-rate windows (shared with
    /// [`crate::monitor::Monitor`] when attached).
    pub const STAGE_RATE: [&str; 3] =
        ["stage_rate_encode", "stage_rate_diffuse", "stage_rate_decode"];
    /// Cascade escalation instruments (control lane).
    pub const CASCADE_ESCALATIONS: &str = "cascade_escalations";
    pub const CASCADE_ESCALATION_WINDOW: &str = "cascade_escalation_window";
    pub const CASCADE_ESCALATION_RATE: &str = "cascade_escalation_rate";
    /// Cascade quality-verdict window (shared with the
    /// [`crate::cascade::ThresholdController`] when attached) + its
    /// sampled attainment series.
    pub const CASCADE_VERDICTS: &str = "cascade_quality_verdicts";
    pub const CASCADE_QUALITY: &str = "cascade_quality_attainment";
    /// Blackout histograms + counters (control lane): planned resizes vs
    /// fault recoveries.
    pub const RESIZE_BLACKOUT_MS: &str = "resize_blackout_ms";
    pub const FAULT_BLACKOUT_MS: &str = "fault_blackout_ms";
    pub const LANE_SWAPS: &str = "lane_swaps";
    pub const FAULT_BLACKOUTS: &str = "fault_blackouts";
    /// Graceful-degradation ladder (control lane): sampled rung severity
    /// (0 = normal … 3 = shed), transition counter, and the per-lane
    /// accounting of arrivals the ladder shed or deferred.
    pub const DEGRADE_LEVEL: &str = "degrade_level";
    pub const DEGRADE_TRANSITIONS: &str = "degrade_transitions";
    pub const REQUESTS_SHED: &str = "requests_shed";
    pub const REQUESTS_DEFERRED: &str = "requests_deferred";
    /// Trace events evicted from a full [`crate::obs::RingSink`] (counter,
    /// control lane). Recorded post-run by whoever owns the sink; exported
    /// as `trident_trace_dropped_total` so a truncated trace is visible in
    /// the metrics snapshot, not just the JSONL trailer.
    pub const TRACE_DROPPED: &str = "trace_dropped";
    /// Control-plane self-profiling phase totals (histogram, control
    /// lane): one wall-ms observation per [`crate::prof::Phase`], bridged
    /// post-run by [`crate::prof::export::bridge_telemetry`] alongside the
    /// per-phase `prof_<phase>_ms` gauge series. Wall-clock values —
    /// present only when profiling is on, never in pinned exports.
    pub const PROF_PHASE_MS: &str = "prof_phase_ms";
}

/// Instrument key: `(metric name, lane)`. Deterministic `Ord` (str content,
/// then lane) keeps every export stable.
pub type Key = (&'static str, u32);

/// The instrument store behind an enabled [`Telemetry`] handle.
#[derive(Default)]
pub struct Registry {
    counters: BTreeMap<Key, u64>,
    gauges: BTreeMap<Key, f64>,
    hists: BTreeMap<Key, LogHistogram>,
    /// Per-instrument time series: `(t_ms, value)` in record order (event
    /// time is monotone per sampler).
    series: BTreeMap<Key, Vec<(f64, f64)>>,
    windows: BTreeMap<Key, Rc<RefCell<RollingWindow>>>,
    verdicts: BTreeMap<Key, Rc<RefCell<VerdictWindow>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &'static str, lane: u32, delta: u64) {
        *self.counters.entry((name, lane)).or_insert(0) += delta;
    }

    pub fn set_gauge(&mut self, name: &'static str, lane: u32, v: f64) {
        self.gauges.insert((name, lane), v);
    }

    /// Gauge + time-series point.
    pub fn sample(&mut self, t_ms: f64, name: &'static str, lane: u32, v: f64) {
        self.gauges.insert((name, lane), v);
        self.series.entry((name, lane)).or_default().push((t_ms, v));
    }

    pub fn observe(&mut self, name: &'static str, lane: u32, v: f64) {
        self.hists.entry((name, lane)).or_default().record(v);
    }

    /// Get-or-create the shared rolling window for `(name, lane)`. The
    /// `window_ms` applies only at creation; later callers share the
    /// existing window regardless.
    pub fn window(
        &mut self,
        name: &'static str,
        lane: u32,
        window_ms: f64,
    ) -> Rc<RefCell<RollingWindow>> {
        self.windows
            .entry((name, lane))
            .or_insert_with(|| Rc::new(RefCell::new(RollingWindow::new(window_ms))))
            .clone()
    }

    /// Get-or-create the shared verdict window for `(name, lane)` (`cap`
    /// applies only at creation).
    pub fn verdicts(
        &mut self,
        name: &'static str,
        lane: u32,
        cap: usize,
    ) -> Rc<RefCell<VerdictWindow>> {
        self.verdicts
            .entry((name, lane))
            .or_insert_with(|| Rc::new(RefCell::new(VerdictWindow::new(cap))))
            .clone()
    }

    pub fn counter(&self, name: &'static str, lane: u32) -> Option<u64> {
        self.counters.get(&(name, lane)).copied()
    }

    pub fn gauge(&self, name: &'static str, lane: u32) -> Option<f64> {
        self.gauges.get(&(name, lane)).copied()
    }

    pub fn hist(&self, name: &'static str, lane: u32) -> Option<&LogHistogram> {
        self.hists.get(&(name, lane))
    }

    /// Cluster roll-up: every lane's `name` histogram merged (associative,
    /// so grouping order is irrelevant). `None` when no lane recorded it.
    pub fn merged_hist(&self, name: &str) -> Option<LogHistogram> {
        let mut out: Option<LogHistogram> = None;
        for ((n, _), h) in &self.hists {
            if *n != name {
                continue;
            }
            match &mut out {
                Some(acc) => acc.merge(h),
                None => out = Some(h.clone()),
            }
        }
        out
    }

    pub fn series_of(&self, name: &'static str, lane: u32) -> Option<&[(f64, f64)]> {
        self.series.get(&(name, lane)).map(|v| v.as_slice())
    }

    // Exporter views (deterministically ordered).
    pub fn counters(&self) -> &BTreeMap<Key, u64> {
        &self.counters
    }

    pub fn gauges(&self) -> &BTreeMap<Key, f64> {
        &self.gauges
    }

    pub fn hists(&self) -> &BTreeMap<Key, LogHistogram> {
        &self.hists
    }

    pub fn series(&self) -> &BTreeMap<Key, Vec<(f64, f64)>> {
        &self.series
    }
}

/// Cheap, cloneable instrument handle — the telemetry twin of
/// [`crate::obs::Tracer`]. Every instrumented component holds one; clones
/// share the registry. [`Telemetry::off()`] (the default everywhere) is a
/// `None` registry: every instrument call is one branch, no allocation.
#[derive(Clone)]
pub struct Telemetry {
    lane: u32,
    sink: Option<Rc<RefCell<Registry>>>,
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::off()
    }
}

impl Telemetry {
    /// Disabled handle: all instrument calls short-circuit.
    pub fn off() -> Telemetry {
        Telemetry { lane: CONTROL_LANE, sink: None }
    }

    /// Fresh registry + its enabled handle (control-lane stamped; fan out
    /// with [`Telemetry::for_lane`]).
    pub fn registry() -> (Telemetry, Rc<RefCell<Registry>>) {
        let reg = Rc::new(RefCell::new(Registry::new()));
        (Telemetry { lane: CONTROL_LANE, sink: Some(reg.clone()) }, reg)
    }

    /// Handle over an existing registry.
    pub fn with_registry(reg: Rc<RefCell<Registry>>) -> Telemetry {
        Telemetry { lane: CONTROL_LANE, sink: Some(reg) }
    }

    /// A clone stamped with a lane id.
    pub fn for_lane(&self, lane: u32) -> Telemetry {
        Telemetry { lane, sink: self.sink.clone() }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    pub fn lane(&self) -> u32 {
        self.lane
    }

    /// Increment a counter.
    #[inline]
    pub fn add(&self, name: &'static str, delta: u64) {
        if let Some(reg) = &self.sink {
            reg.borrow_mut().add(name, self.lane, delta);
        }
    }

    /// Record into a streaming histogram.
    #[inline]
    pub fn observe(&self, name: &'static str, v: f64) {
        if let Some(reg) = &self.sink {
            reg.borrow_mut().observe(name, self.lane, v);
        }
    }

    /// Set a gauge (no time-series point).
    #[inline]
    pub fn gauge(&self, name: &'static str, v: f64) {
        if let Some(reg) = &self.sink {
            reg.borrow_mut().set_gauge(name, self.lane, v);
        }
    }

    /// Set a gauge and append a `(t_ms, v)` time-series point.
    #[inline]
    pub fn sample(&self, t_ms: f64, name: &'static str, v: f64) {
        if let Some(reg) = &self.sink {
            reg.borrow_mut().sample(t_ms, name, self.lane, v);
        }
    }

    /// Push a weighted event into the shared rolling window `name`
    /// (created at [`DEFAULT_WINDOW_MS`] on first touch).
    #[inline]
    pub fn push_window(&self, name: &'static str, t_ms: f64, weight: f64) {
        if let Some(reg) = &self.sink {
            let w = reg.borrow_mut().window(name, self.lane, DEFAULT_WINDOW_MS);
            w.borrow_mut().push(t_ms, weight);
        }
    }

    /// Mean weight of the shared rolling window `name` (None when off, or
    /// when the window is absent/empty).
    pub fn window_mean(&self, name: &'static str, now_ms: f64) -> Option<f64> {
        let reg = self.sink.as_ref()?;
        let w = reg.borrow().windows.get(&(name, self.lane)).cloned()?;
        let m = w.borrow_mut().mean_weight(now_ms);
        m
    }

    /// Rate (weight/s) of the shared rolling window `name`.
    pub fn window_rate(&self, name: &'static str, now_ms: f64) -> Option<f64> {
        let reg = self.sink.as_ref()?;
        let w = reg.borrow().windows.get(&(name, self.lane)).cloned()?;
        let r = w.borrow_mut().rate_per_sec(now_ms);
        Some(r)
    }

    /// Quantile of this lane's `name` histogram.
    pub fn hist_quantile(&self, name: &'static str, q: f64) -> Option<f64> {
        let reg = self.sink.as_ref()?;
        let reg = reg.borrow();
        reg.hist(name, self.lane)?.quantile(q)
    }

    /// Shared rolling-window handle for closed-loop consumers (None when
    /// off — the consumer keeps its private window).
    pub fn shared_window(
        &self,
        name: &'static str,
        window_ms: f64,
    ) -> Option<Rc<RefCell<RollingWindow>>> {
        let reg = self.sink.as_ref()?;
        Some(reg.borrow_mut().window(name, self.lane, window_ms))
    }

    /// Shared verdict-window handle for closed-loop consumers.
    pub fn shared_verdicts(
        &self,
        name: &'static str,
        cap: usize,
    ) -> Option<Rc<RefCell<VerdictWindow>>> {
        let reg = self.sink.as_ref()?;
        Some(reg.borrow_mut().verdicts(name, self.lane, cap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_is_inert() {
        let t = Telemetry::off();
        assert!(!t.enabled());
        t.add(metric::REQUESTS_COMPLETED, 1);
        t.observe(metric::REQUEST_LATENCY_MS, 5.0);
        t.sample(0.0, metric::QUEUE_DEPTH, 1.0);
        t.push_window(metric::SLO_WINDOW, 0.0, 1.0);
        assert_eq!(t.window_mean(metric::SLO_WINDOW, 0.0), None);
        assert_eq!(t.hist_quantile(metric::REQUEST_LATENCY_MS, 0.5), None);
        assert!(t.shared_window(metric::SLO_WINDOW, 1000.0).is_none());
        assert!(t.shared_verdicts(metric::CASCADE_VERDICTS, 8).is_none());
    }

    #[test]
    fn instruments_record_per_lane_and_roll_up() {
        let (t, reg) = Telemetry::registry();
        let (l0, l1) = (t.for_lane(0), t.for_lane(1));
        l0.add(metric::REQUESTS_COMPLETED, 2);
        l1.add(metric::REQUESTS_COMPLETED, 3);
        l0.observe(metric::REQUEST_LATENCY_MS, 10.0);
        l1.observe(metric::REQUEST_LATENCY_MS, 1000.0);
        l0.sample(5.0, metric::QUEUE_DEPTH, 7.0);

        let r = reg.borrow();
        assert_eq!(r.counter(metric::REQUESTS_COMPLETED, 0), Some(2));
        assert_eq!(r.counter(metric::REQUESTS_COMPLETED, 1), Some(3));
        assert_eq!(r.gauge(metric::QUEUE_DEPTH, 0), Some(7.0));
        assert_eq!(r.series_of(metric::QUEUE_DEPTH, 0), Some(&[(5.0, 7.0)][..]));
        let merged = r.merged_hist(metric::REQUEST_LATENCY_MS).unwrap();
        assert_eq!(merged.count(), 2);
        assert_eq!(merged.min(), Some(10.0));
        assert_eq!(merged.max(), Some(1000.0));
    }

    #[test]
    fn shared_windows_are_one_object() {
        let (t, _reg) = Telemetry::registry();
        let l0 = t.for_lane(0);
        let handle = l0.shared_window(metric::SLO_WINDOW, 60_000.0).unwrap();
        // The instrument path and the controller handle see the same window.
        l0.push_window(metric::SLO_WINDOW, 100.0, 1.0);
        l0.push_window(metric::SLO_WINDOW, 200.0, 0.0);
        assert_eq!(handle.borrow().len(), 2);
        assert_eq!(l0.window_mean(metric::SLO_WINDOW, 200.0), Some(0.5));
        // And vice versa: a push through the handle is visible to reads.
        handle.borrow_mut().push(300.0, 0.0);
        assert!((l0.window_mean(metric::SLO_WINDOW, 300.0).unwrap() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn verdict_handles_share_state() {
        let (t, _reg) = Telemetry::registry();
        let a = t.shared_verdicts(metric::CASCADE_VERDICTS, 4).unwrap();
        let b = t.shared_verdicts(metric::CASCADE_VERDICTS, 999).unwrap(); // cap ignored: existing
        a.borrow_mut().observe(true);
        assert_eq!(b.borrow().observed(), 1);
        assert_eq!(b.borrow().cap(), 4);
    }
}
