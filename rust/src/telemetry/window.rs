//! Rolling windows: the shared observe→decide signal types.
//!
//! Two shapes cover every controller in the tree:
//!
//! * [`RollingWindow`] — time-based (`window_ms`), weighted events. The
//!   Monitor's per-stage throughput estimator, the lanes' demand windows,
//!   and the telemetry samplers' rate/attainment signals are all this type
//!   (`util::stats::SlidingWindow` is a re-export). Registered in a
//!   [`crate::telemetry::Registry`] it becomes a *shared* handle: the
//!   instrument that records into it and the controller that reads it see
//!   the same window.
//! * [`VerdictWindow`] — count-capped boolean ring: the cascade
//!   [`crate::cascade::ThresholdController`]'s quality-verdict evidence,
//!   with the total-observed counter its stale-evidence guard keys on.

use std::collections::VecDeque;

/// Time-based sliding window over `(t_ms, weight)` events, evicting
/// entries older than `window_ms` on every push/read.
#[derive(Clone, Debug)]
pub struct RollingWindow {
    window_ms: f64,
    events: VecDeque<(f64, f64)>, // (t_ms, weight)
}

impl RollingWindow {
    pub fn new(window_ms: f64) -> Self {
        RollingWindow { window_ms, events: Default::default() }
    }

    pub fn window_ms(&self) -> f64 {
        self.window_ms
    }

    pub fn push(&mut self, t_ms: f64, weight: f64) {
        self.events.push_back((t_ms, weight));
        self.evict(t_ms);
    }

    /// Drop all retained events (a consumer re-adopting a shared window
    /// starts from fresh evidence, e.g. a lane monitor after an engine
    /// rebuild).
    pub fn clear(&mut self) {
        self.events.clear();
    }

    fn evict(&mut self, now_ms: f64) {
        while let Some(&(t, _)) = self.events.front() {
            if now_ms - t > self.window_ms {
                self.events.pop_front();
            } else {
                break;
            }
        }
    }

    /// Weighted events per second over the window ending at `now_ms`.
    pub fn rate_per_sec(&mut self, now_ms: f64) -> f64 {
        self.evict(now_ms);
        let sum: f64 = self.events.iter().map(|&(_, w)| w).sum();
        sum / (self.window_ms / 1000.0)
    }

    /// Total weight currently in the window ending at `now_ms`.
    pub fn sum_weight(&mut self, now_ms: f64) -> f64 {
        self.evict(now_ms);
        self.events.iter().map(|&(_, w)| w).sum()
    }

    /// Mean weight per event in the window ending at `now_ms` — the
    /// attainment read when weights are 0/1 verdicts. `None` when empty
    /// ("no data" must never masquerade as a measured 0).
    pub fn mean_weight(&mut self, now_ms: f64) -> Option<f64> {
        self.evict(now_ms);
        if self.events.is_empty() {
            return None;
        }
        Some(self.events.iter().map(|&(_, w)| w).sum::<f64>() / self.events.len() as f64)
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Count-capped boolean verdict ring + total-observed counter.
#[derive(Clone, Debug)]
pub struct VerdictWindow {
    cap: usize,
    window: VecDeque<bool>,
    observed: u64,
}

impl VerdictWindow {
    pub fn new(cap: usize) -> Self {
        VerdictWindow { cap: cap.max(1), window: VecDeque::new(), observed: 0 }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn observe(&mut self, ok: bool) {
        self.window.push_back(ok);
        self.observed += 1;
        if self.window.len() > self.cap {
            self.window.pop_front();
        }
    }

    /// Total verdicts ever observed (not just the retained window) — the
    /// stale-evidence guard's clock.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Fraction of retained verdicts that are `true`; `None` when empty.
    pub fn frac_ok(&self) -> Option<f64> {
        if self.window.is_empty() {
            return None;
        }
        let ok = self.window.iter().filter(|&&q| q).count();
        Some(ok as f64 / self.window.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rolling_window_mean_and_sum() {
        let mut w = RollingWindow::new(1000.0);
        assert_eq!(w.mean_weight(0.0), None);
        w.push(0.0, 1.0);
        w.push(500.0, 0.0);
        assert_eq!(w.mean_weight(500.0), Some(0.5));
        assert_eq!(w.sum_weight(500.0), 1.0);
        // t=0 ages out at t=1600: only the 0-weight verdict remains.
        assert_eq!(w.mean_weight(1600.0), Some(0.0));
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn rolling_window_empty_and_single_sample() {
        let mut w = RollingWindow::new(1000.0);
        // Empty: rate is a measured 0/s, mean is "no data" (None) — the two
        // must not be conflated.
        assert_eq!(w.rate_per_sec(0.0), 0.0);
        assert_eq!(w.sum_weight(1e9), 0.0);
        assert_eq!(w.mean_weight(1e9), None);
        assert!(w.is_empty());
        // One sample: every read is that sample.
        w.push(100.0, 3.0);
        assert_eq!(w.len(), 1);
        assert_eq!(w.mean_weight(100.0), Some(3.0));
        assert_eq!(w.sum_weight(100.0), 3.0);
        assert_eq!(w.rate_per_sec(100.0), 3.0); // 3 weight / 1s window
        // clear() returns to the empty-window readings exactly.
        w.clear();
        assert!(w.is_empty());
        assert_eq!(w.mean_weight(100.0), None);
    }

    #[test]
    fn rolling_window_out_of_order_and_equal_timestamps() {
        let mut w = RollingWindow::new(1000.0);
        // Eviction keys on the *read* clock, not insertion order: a sample
        // pushed with an older timestamp is retained as long as it is
        // within the window of the latest read.
        w.push(800.0, 1.0);
        w.push(200.0, 2.0); // out of order — push evicts against t=200 only
        assert_eq!(w.len(), 2);
        assert_eq!(w.sum_weight(900.0), 3.0);
        // Equal timestamps all count.
        w.push(900.0, 1.0);
        w.push(900.0, 1.0);
        assert_eq!(w.sum_weight(900.0), 5.0);
        assert_eq!(w.mean_weight(900.0), Some(1.25));
        // The out-of-order t=200 sample ages out first even though it was
        // pushed second; VecDeque order means the front (t=800) shields it
        // until a read advances the clock far enough.
        assert_eq!(w.sum_weight(1500.0), 5.0, "t=200 behind t=800 front survives front check");
        assert_eq!(w.sum_weight(1801.0), 2.0, "t=800 and the shielded t=200 both evict");
    }

    #[test]
    fn rolling_window_boundary_eviction_is_strict() {
        let mut w = RollingWindow::new(1000.0);
        w.push(0.0, 1.0);
        // Exactly window_ms old is retained (strict `>` age check) ...
        assert_eq!(w.sum_weight(1000.0), 1.0);
        assert_eq!(w.len(), 1);
        // ... and one tick past the boundary evicts.
        assert_eq!(w.sum_weight(1000.0000001), 0.0);
        assert!(w.is_empty());
    }

    #[test]
    fn verdict_window_single_sample_and_cap_floor() {
        // cap 0 clamps to 1: the ring is never unbounded-empty.
        let mut v = VerdictWindow::new(0);
        assert_eq!(v.cap(), 1);
        assert_eq!(v.frac_ok(), None);
        v.observe(true);
        assert_eq!((v.len(), v.frac_ok()), (1, Some(1.0)));
        // Every further verdict displaces the previous one exactly.
        v.observe(false);
        assert_eq!((v.len(), v.frac_ok()), (1, Some(0.0)));
        assert_eq!(v.observed(), 2, "observed counts evicted verdicts too");
    }

    #[test]
    fn verdict_window_caps_and_counts() {
        let mut v = VerdictWindow::new(4);
        assert_eq!(v.frac_ok(), None);
        for _ in 0..4 {
            v.observe(false);
        }
        assert_eq!(v.frac_ok(), Some(0.0));
        for _ in 0..4 {
            v.observe(true); // displaces the failing prefix entirely
        }
        assert_eq!(v.frac_ok(), Some(1.0));
        assert_eq!(v.len(), 4);
        assert_eq!(v.observed(), 8);
    }
}
