//! Machine-readable bench output.
//!
//! Every bench records `{bench, metric, value}` rows through a
//! [`BenchRecorder`] and writes them to `BENCH_<name>.json` (repo root by
//! default, `BENCH_OUT_DIR` to override) so the perf trajectory is tracked
//! across PRs: CI's perf-smoke job uploads the file as an artifact, and a
//! reviewer can diff the numbers instead of eyeballing stdout.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Collects `{bench, metric, value}` records and serialises them as a JSON
/// array (one object per record).
pub struct BenchRecorder {
    bench: String,
    records: Vec<(String, f64)>,
}

impl BenchRecorder {
    pub fn new(bench: &str) -> Self {
        BenchRecorder { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one record. Non-finite values are clamped to 0 (JSON has no
    /// NaN/Inf and a poisoned file would break downstream diffing).
    pub fn record(&mut self, metric: &str, value: f64) {
        let v = if value.is_finite() { value } else { 0.0 };
        self.records.push((metric.to_string(), v));
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The records as a JSON value (an array of `{bench, metric, value}`).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|(metric, value)| {
                    let mut obj = BTreeMap::new();
                    obj.insert("bench".to_string(), Json::Str(self.bench.clone()));
                    obj.insert("metric".to_string(), Json::Str(metric.clone()));
                    obj.insert("value".to_string(), Json::Num(*value));
                    Json::Obj(obj)
                })
                .collect(),
        )
    }

    /// Default output path: `$BENCH_OUT_DIR/BENCH_<name>.json`, falling
    /// back to the current directory (the repo root under `cargo bench`).
    pub fn default_path(&self) -> PathBuf {
        let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".to_string());
        PathBuf::from(dir).join(format!("BENCH_{}.json", self.bench))
    }

    /// Write to an explicit directory; returns the file path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, format!("{}\n", self.to_json().to_string()))?;
        Ok(path)
    }

    /// Write to the default path; returns it.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.default_path();
        std::fs::write(&path, format!("{}\n", self.to_json().to_string()))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_serialise_round_trip() {
        let mut b = BenchRecorder::new("unit");
        b.record("alpha_ms", 1.5);
        b.record("beta", 2.0);
        b.record("bad", f64::NAN); // clamped, not poisoned
        assert_eq!(b.len(), 3);
        let text = b.to_json().to_string();
        let parsed = Json::parse(&text).expect("recorder output must parse");
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("bench").and_then(|j| j.as_str()), Some("unit"));
        assert_eq!(arr[0].get("metric").and_then(|j| j.as_str()), Some("alpha_ms"));
        assert_eq!(arr[0].get("value").and_then(|j| j.as_f64()), Some(1.5));
        assert_eq!(arr[2].get("value").and_then(|j| j.as_f64()), Some(0.0));
    }

    #[test]
    fn writes_file_to_explicit_dir() {
        let mut b = BenchRecorder::new("unit_write");
        b.record("m", 3.0);
        let dir = std::env::temp_dir();
        let path = b.write_to(&dir).expect("write must succeed");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("unit_write"));
        let _ = std::fs::remove_file(path);
    }
}
