//! Machine-readable bench output and the perf-regression comparator.
//!
//! Every bench records `{bench, metric, value}` rows through a
//! [`BenchRecorder`] and writes them to `BENCH_<name>.json` (repo root by
//! default, `BENCH_OUT_DIR` to override) so the perf trajectory is tracked
//! across PRs: CI's perf-smoke job uploads the file as an artifact, and a
//! reviewer can diff the numbers instead of eyeballing stdout.
//!
//! [`compare_benches`] closes the loop: CI diffs a freshly-produced bench
//! file against the committed baseline with per-metric tolerances (time
//! suffixes regress *upward*, throughput regresses *downward*, everything
//! else must match exactly) and fails the job on regression — see the
//! `bench-check` subcommand in `main.rs`. An empty committed baseline
//! (`[]`, the bootstrap state) compares as trivially passing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Collects `{bench, metric, value}` records and serialises them as a JSON
/// array (one object per record).
pub struct BenchRecorder {
    bench: String,
    records: Vec<(String, f64)>,
}

impl BenchRecorder {
    pub fn new(bench: &str) -> Self {
        BenchRecorder { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one record. Non-finite values are clamped to 0 (JSON has no
    /// NaN/Inf and a poisoned file would break downstream diffing).
    pub fn record(&mut self, metric: &str, value: f64) {
        let v = if value.is_finite() { value } else { 0.0 };
        self.records.push((metric.to_string(), v));
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The records as a JSON value (an array of `{bench, metric, value}`).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|(metric, value)| {
                    let mut obj = BTreeMap::new();
                    obj.insert("bench".to_string(), Json::Str(self.bench.clone()));
                    obj.insert("metric".to_string(), Json::Str(metric.clone()));
                    obj.insert("value".to_string(), Json::Num(*value));
                    Json::Obj(obj)
                })
                .collect(),
        )
    }

    /// Default output path: `$BENCH_OUT_DIR/BENCH_<name>.json`, falling
    /// back to the current directory (the repo root under `cargo bench`).
    pub fn default_path(&self) -> PathBuf {
        let dir = std::env::var("BENCH_OUT_DIR").unwrap_or_else(|_| ".".to_string());
        PathBuf::from(dir).join(format!("BENCH_{}.json", self.bench))
    }

    /// Write to an explicit directory; returns the file path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, format!("{}\n", self.to_json().to_string()))?;
        Ok(path)
    }

    /// Write to the default path; returns it.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.default_path();
        std::fs::write(&path, format!("{}\n", self.to_json().to_string()))?;
        Ok(path)
    }
}

/// Relative headroom for time-like metrics (`*_ns`/`*_us`/`*_ms`/`*_s`):
/// wall-clock microbenchmarks on shared CI runners are noisy, so only a
/// slowdown beyond +75% fails the gate.
pub const TIME_TOLERANCE: f64 = 0.75;
/// Relative headroom for throughput-like metrics (`*_rps`, `*_per_sec`):
/// down is bad; a drop beyond -40% fails.
pub const RATE_TOLERANCE: f64 = 0.40;
/// Everything else (counts, ratios, sizes) is deterministic in this
/// simulator and must match the baseline up to float noise.
pub const EXACT_TOLERANCE: f64 = 1e-9;
/// Absolute headroom for fitted scaling exponents (`*_exponent`, the
/// `scale_sweep` complexity gate): log-log slopes are dimensionless and
/// already noise-averaged across the sweep grid, so the gate is an absolute
/// band — a phase whose exponent grows by more than this (e.g. an
/// O(1)-per-event phase going superlinear) fails; a shrinking exponent is
/// an improvement and never fails.
pub const EXPONENT_TOLERANCE: f64 = 0.35;

/// Least-squares slope of `ln(y)` against `ln(x)` — the fitted scaling
/// exponent the scale-sweep bench records per phase (`<phase>_exponent`).
/// Non-positive samples are floored at 1 (a phase measured at 0 ns still
/// fits; `ln(0)` would poison the fit), and a degenerate sweep (fewer than
/// two distinct x values) fits as 0 (no scaling evidence).
pub fn fit_loglog_exponent(points: &[(f64, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|(x, _)| *x > 0.0)
        .map(|&(x, y)| (x.ln(), y.max(1.0).ln()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let n = pts.len() as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let sxx: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let sxy: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    if sxx <= 0.0 {
        return 0.0; // all points at one scale
    }
    sxy / sxx
}

/// Which direction a metric regresses in, and how much headroom it gets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Durations: regression = value grew beyond the tolerance.
    Time,
    /// Throughput: regression = value shrank beyond the tolerance.
    Rate,
    /// Fitted complexity exponents: regression = slope grew by more than
    /// the absolute [`EXPONENT_TOLERANCE`] band.
    Exponent,
    /// Deterministic outputs: regression = any drift beyond float noise.
    Exact,
}

/// Classify a metric by naming convention (the same suffix discipline every
/// bench in `benches/` already follows).
pub fn metric_kind(metric: &str) -> MetricKind {
    // `_exponent` first: it must not fall through to the Exact default
    // (fitted slopes are real-valued and jitter run to run).
    if metric.ends_with("_exponent") {
        return MetricKind::Exponent;
    }
    let time_suffix = ["_ns", "_us", "_ms", "_s"].iter().any(|s| metric.ends_with(s));
    if time_suffix || metric.contains("latency") {
        MetricKind::Time
    } else if metric.ends_with("_rps") || metric.ends_with("_per_sec") || metric.contains("throughput") {
        MetricKind::Rate
    } else {
        MetricKind::Exact
    }
}

/// One baseline/current pair, compared.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub bench: String,
    pub metric: String,
    pub kind: MetricKind,
    pub baseline: f64,
    pub current: f64,
    pub regressed: bool,
}

impl BenchDelta {
    fn compare(bench: String, metric: String, baseline: f64, current: f64) -> BenchDelta {
        let kind = metric_kind(&metric);
        let regressed = match kind {
            MetricKind::Time => current > baseline * (1.0 + TIME_TOLERANCE) + 1e-12,
            MetricKind::Rate => current < baseline * (1.0 - RATE_TOLERANCE) - 1e-12,
            MetricKind::Exponent => current > baseline + EXPONENT_TOLERANCE + 1e-12,
            MetricKind::Exact => {
                (current - baseline).abs() > baseline.abs().max(1.0) * EXACT_TOLERANCE
            }
        };
        BenchDelta { bench, metric, kind, baseline, current, regressed }
    }
}

/// Outcome of diffing a fresh bench file against the committed baseline.
#[derive(Clone, Debug, Default)]
pub struct RegressionReport {
    /// Every metric present in both files, compared.
    pub deltas: Vec<BenchDelta>,
    /// `(bench, metric)` present in the baseline but missing from the
    /// current run — a silently-vanished measurement fails the gate.
    pub missing: Vec<(String, String)>,
    /// Present only in the current run (new metrics: informational).
    pub added: Vec<(String, String)>,
    /// The committed baseline was `[]` (bootstrap): nothing to gate on.
    pub empty_baseline: bool,
}

impl RegressionReport {
    pub fn regressions(&self) -> Vec<&BenchDelta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// Gate verdict: fail on any regressed metric or vanished measurement,
    /// except in the fail-soft bootstrap state (empty baseline).
    pub fn failed(&self) -> bool {
        !self.empty_baseline && (!self.missing.is_empty() || self.deltas.iter().any(|d| d.regressed))
    }
}

impl std::fmt::Display for RegressionReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.empty_baseline {
            return writeln!(f, "bench-check: baseline is empty (bootstrap); nothing to gate on");
        }
        for d in &self.deltas {
            let verdict = if d.regressed { "REGRESSED" } else { "ok" };
            writeln!(
                f,
                "{verdict:>9}  {}/{} [{:?}]  {} -> {}",
                d.bench, d.metric, d.kind, d.baseline, d.current
            )?;
        }
        for (b, m) in &self.missing {
            writeln!(f, "  MISSING  {b}/{m}  (in baseline, absent from current run)")?;
        }
        for (b, m) in &self.added {
            writeln!(f, "      new  {b}/{m}")?;
        }
        Ok(())
    }
}

/// Parse one `BENCH_*.json` text into `(bench, metric) -> value`. Rejects
/// anything that isn't an array of `{bench, metric, value}` rows.
fn parse_bench_records(text: &str) -> Result<BTreeMap<(String, String), f64>, String> {
    let parsed = Json::parse(text.trim()).map_err(|e| format!("bad bench json: {e}"))?;
    let arr = parsed.as_arr().ok_or("bench file is not a JSON array")?;
    let mut out = BTreeMap::new();
    for row in arr {
        let bench = row
            .get("bench")
            .and_then(|j| j.as_str())
            .ok_or("row missing string field 'bench'")?;
        let metric = row
            .get("metric")
            .and_then(|j| j.as_str())
            .ok_or("row missing string field 'metric'")?;
        let value =
            row.get("value").and_then(|j| j.as_f64()).ok_or("row missing number field 'value'")?;
        out.insert((bench.to_string(), metric.to_string()), value);
    }
    Ok(out)
}

/// Diff a fresh bench file against the committed baseline (both as raw
/// `BENCH_*.json` text). Per-metric tolerances by naming convention; see
/// [`RegressionReport::failed`] for the gate verdict.
pub fn compare_benches(baseline: &str, current: &str) -> Result<RegressionReport, String> {
    let base = parse_bench_records(baseline)?;
    let cur = parse_bench_records(current)?;
    if base.is_empty() {
        return Ok(RegressionReport { empty_baseline: true, ..Default::default() });
    }
    let mut report = RegressionReport::default();
    for ((bench, metric), &bv) in &base {
        match cur.get(&(bench.clone(), metric.clone())) {
            Some(&cv) => report
                .deltas
                .push(BenchDelta::compare(bench.clone(), metric.clone(), bv, cv)),
            None => report.missing.push((bench.clone(), metric.clone())),
        }
    }
    for (bench, metric) in cur.keys() {
        if !base.contains_key(&(bench.clone(), metric.clone())) {
            report.added.push((bench.clone(), metric.clone()));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_serialise_round_trip() {
        let mut b = BenchRecorder::new("unit");
        b.record("alpha_ms", 1.5);
        b.record("beta", 2.0);
        b.record("bad", f64::NAN); // clamped, not poisoned
        assert_eq!(b.len(), 3);
        let text = b.to_json().to_string();
        let parsed = Json::parse(&text).expect("recorder output must parse");
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("bench").and_then(|j| j.as_str()), Some("unit"));
        assert_eq!(arr[0].get("metric").and_then(|j| j.as_str()), Some("alpha_ms"));
        assert_eq!(arr[0].get("value").and_then(|j| j.as_f64()), Some(1.5));
        assert_eq!(arr[2].get("value").and_then(|j| j.as_f64()), Some(0.0));
    }

    fn bench_json(rows: &[(&str, &str, f64)]) -> String {
        let mut b: BTreeMap<&str, BenchRecorder> = BTreeMap::new();
        for &(bench, metric, v) in rows {
            b.entry(bench).or_insert_with(|| BenchRecorder::new(bench)).record(metric, v);
        }
        let all: Vec<Json> = b
            .values()
            .flat_map(|r| r.to_json().as_arr().unwrap().to_vec())
            .collect();
        Json::Arr(all).to_string()
    }

    #[test]
    fn comparator_applies_per_kind_tolerances() {
        assert_eq!(metric_kind("emit_ns"), MetricKind::Time);
        assert_eq!(metric_kind("p99_latency"), MetricKind::Time);
        assert_eq!(metric_kind("served_rps"), MetricKind::Rate);
        assert_eq!(metric_kind("events"), MetricKind::Exact);
        let base = bench_json(&[
            ("hot", "emit_ns", 100.0),
            ("hot", "served_rps", 50.0),
            ("hot", "events", 7.0),
        ]);
        // Inside every tolerance: time +50% < +75%, rate -20% < -40%, exact
        // unchanged.
        let ok = bench_json(&[
            ("hot", "emit_ns", 150.0),
            ("hot", "served_rps", 40.0),
            ("hot", "events", 7.0),
        ]);
        let rep = compare_benches(&base, &ok).unwrap();
        assert!(!rep.failed(), "{rep}");
        assert_eq!(rep.regressions().len(), 0);
        // Each kind violated in its bad direction.
        let bad = bench_json(&[
            ("hot", "emit_ns", 200.0),   // +100% > +75%
            ("hot", "served_rps", 20.0), // -60% > -40%
            ("hot", "events", 8.0),      // deterministic drift
        ]);
        let rep = compare_benches(&base, &bad).unwrap();
        assert!(rep.failed());
        assert_eq!(rep.regressions().len(), 3);
        // Improvements never fail: faster time, higher rate.
        let better = bench_json(&[
            ("hot", "emit_ns", 10.0),
            ("hot", "served_rps", 500.0),
            ("hot", "events", 7.0),
        ]);
        assert!(!compare_benches(&base, &better).unwrap().failed());
    }

    #[test]
    fn exponent_metrics_gate_on_absolute_slope_growth() {
        assert_eq!(metric_kind("mckp_solve_exponent"), MetricKind::Exponent);
        // `_exponent` wins over the `_s`-ish suffix fallthrough and never
        // lands in Exact.
        assert_eq!(metric_kind("free_view_exponent"), MetricKind::Exponent);
        let base = bench_json(&[("sweep", "free_view_exponent", 1.0)]);
        // Within the band: slope drift +0.2 < +0.35 passes.
        let ok = bench_json(&[("sweep", "free_view_exponent", 1.2)]);
        assert!(!compare_benches(&base, &ok).unwrap().failed());
        // A linear phase going quadratic fails the gate.
        let bad = bench_json(&[("sweep", "free_view_exponent", 2.0)]);
        let rep = compare_benches(&base, &bad).unwrap();
        assert!(rep.failed());
        assert_eq!(rep.regressions().len(), 1);
        assert_eq!(rep.regressions()[0].kind, MetricKind::Exponent);
        // Improvement (sublinear) never fails.
        let better = bench_json(&[("sweep", "free_view_exponent", 0.3)]);
        assert!(!compare_benches(&base, &better).unwrap().failed());
    }

    #[test]
    fn loglog_fit_recovers_known_exponents() {
        // y = 3 x^2 exactly -> slope 2.
        let quad: Vec<(f64, f64)> =
            [16.0, 64.0, 256.0].iter().map(|&x: &f64| (x, 3.0 * x * x)).collect();
        assert!((fit_loglog_exponent(&quad) - 2.0).abs() < 1e-9);
        // Constant cost -> slope 0.
        let flat = [(16.0, 5000.0), (64.0, 5000.0), (256.0, 5000.0)];
        assert!(fit_loglog_exponent(&flat).abs() < 1e-9);
        // Degenerate inputs fit as 0, never NaN.
        assert_eq!(fit_loglog_exponent(&[]), 0.0);
        assert_eq!(fit_loglog_exponent(&[(16.0, 1.0)]), 0.0);
        assert_eq!(fit_loglog_exponent(&[(16.0, 1.0), (16.0, 9.0)]), 0.0);
        // Zero-valued samples are floored, not ln(0)-poisoned.
        let zeros = [(16.0, 0.0), (64.0, 0.0)];
        assert!(fit_loglog_exponent(&zeros).is_finite());
    }

    #[test]
    fn vanished_metrics_fail_and_new_ones_are_informational() {
        let base = bench_json(&[("hot", "emit_ns", 100.0)]);
        let cur = bench_json(&[("hot", "other_ns", 1.0)]);
        let rep = compare_benches(&base, &cur).unwrap();
        assert!(rep.failed());
        assert_eq!(rep.missing, vec![("hot".to_string(), "emit_ns".to_string())]);
        assert_eq!(rep.added, vec![("hot".to_string(), "other_ns".to_string())]);
    }

    #[test]
    fn empty_baseline_is_fail_soft() {
        let rep = compare_benches("[]\n", &bench_json(&[("hot", "emit_ns", 1.0)])).unwrap();
        assert!(rep.empty_baseline);
        assert!(!rep.failed());
        assert!(format!("{rep}").contains("bootstrap"));
        // Malformed input is an error, not a pass.
        assert!(compare_benches("{", "[]").is_err());
        assert!(compare_benches("[]", "[{\"bench\":1}]").is_err());
    }

    #[test]
    fn writes_file_to_explicit_dir() {
        let mut b = BenchRecorder::new("unit_write");
        b.record("m", 3.0);
        let dir = std::env::temp_dir();
        let path = b.write_to(&dir).expect("write must succeed");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("unit_write"));
        let _ = std::fs::remove_file(path);
    }
}
