//! Minimal error substrate: a string-backed error with `anyhow`-shaped
//! ergonomics (`anyhow!`, `bail!`, `.context(..)`) so the crate builds with
//! zero external dependencies. Fidelity targets the call sites this repo
//! actually has — config parsing, artifact loading, CLI flag parsing — not
//! the full anyhow API.

use std::fmt;

/// A boxed-string error. Construction goes through [`Error::msg`] or the
/// crate-level `anyhow!` macro.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error::msg(msg)
    }
}

/// Crate-standard result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on results and options.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] — drop-in for `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Early-return with a formatted [`Error`] — drop-in for `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_formats() {
        let e = crate::anyhow!("bad value: {}", 7);
        assert_eq!(e.to_string(), "bad value: 7");
    }

    #[test]
    fn context_wraps_error() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                crate::bail!("negative: {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
    }

    #[test]
    fn question_mark_converts_parse_errors() {
        fn f(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(f("12").unwrap(), 12);
        assert!(f("nope").is_err());
    }
}
