//! Minimal JSON: a recursive-descent parser (for `artifacts/manifest.json`)
//! and a writer (for experiment result dumps). Supports the full JSON value
//! grammar minus exotic number forms; strings handle `\uXXXX` escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences faithfully.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        if let Ok(chunk) = std::str::from_utf8(&self.b[start..end]) {
                            s.push_str(chunk);
                            self.i = end;
                        } else {
                            s.push('\u{fffd}');
                        }
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_i64(), Some(1));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"k":null,"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        // Raw UTF-8 passthrough.
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }
}
