//! Small self-contained substrates: PRNG, JSON, statistics, property testing.
//!
//! Everything here is hand-rolled because the build is fully offline (only
//! the crates vendored for the `xla` dependency are available). Each piece is
//! deliberately minimal but complete for this repo's needs.

pub mod bench;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use error::{Context, Error, Result};
pub use rng::Rng;
