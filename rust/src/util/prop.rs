//! Tiny property-testing harness (offline stand-in for `proptest`).
//!
//! `run_prop(seed, cases, f)` drives `f` with a fresh deterministic [`Rng`]
//! per case; on failure it reports the failing case index and the per-case
//! seed so the exact input can be replayed in a unit test.
//!
//! Coordinator invariants (routing, batching, placement/dispatch state) are
//! property-tested with this in `rust/src/*/mod.rs` and `rust/tests/`.

use super::rng::Rng;

/// Run `cases` property checks. `f` gets `(case_rng, case_index)` and should
/// panic (e.g. via `assert!`) on violation.
pub fn run_prop<F: FnMut(&mut Rng, usize)>(seed: u64, cases: usize, mut f: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case} (replay with Rng::new({case_seed:#x})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        run_prop(1, 50, |rng, _| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case() {
        run_prop(2, 50, |rng, _| {
            assert!(rng.f64() < 0.9, "hit the tail");
        });
    }
}
