//! Deterministic PRNG (xoshiro256++) with the distributions the simulator
//! needs: uniform, normal, exponential (Poisson arrivals), categorical.
//!
//! Determinism matters: every experiment in EXPERIMENTS.md is reproducible
//! from a seed; traces, placement decisions and property tests all draw from
//! this generator.

/// The SplitMix64 finaliser: one well-mixed u64 from any u64. Shared by
/// [`Rng::new`] seeding and stateless per-id hashing (e.g. the cascade
/// router's deterministic confidence noise) so the mixing constants live in
/// exactly one place.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — fast, high-quality, seedable from a single `u64`.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give unrelated streams.
    /// (Bit-identical to the original inlined SplitMix64 loop: call k
    /// yields `splitmix64(seed + (k-1)·GOLDEN)`.)
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            let out = splitmix64(sm);
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            out
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (events per unit time); used for
    /// Poisson inter-arrival gaps.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn stream_pinned_to_historical_values() {
        // Reference values computed independently (SplitMix64 seeding +
        // xoshiro256++): pins the exact byte stream every seeded trace in
        // the repo depends on, so refactors of the seeding path cannot
        // silently shift it.
        let mut r = Rng::new(42);
        assert_eq!(r.next_u64(), 0xD076_4D4F_4476_689F);
        assert_eq!(r.next_u64(), 0x519E_4174_576F_3791);
        assert_eq!(r.next_u64(), 0xFBE0_7CFB_0C24_ED8C);
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(42), 0xBDD7_3226_2FEB_6E95);
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        let frac = counts[1] as f64 / 30_000.0;
        assert!((frac - 0.5).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
