//! Streaming and batch statistics used by the metrics layer and benches.

/// Percentile over a sample by linear interpolation (like numpy's default).
/// `q` in `[0, 100]`. Returns `None` on an empty slice.
pub fn percentile(xs: &[f64], q: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(percentile_sorted(&v, q))
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Arithmetic mean. `None` on an empty slice — callers that want a sentinel
/// must choose it explicitly (`mean(xs).unwrap_or(0.0)`), so "no data" can
/// never masquerade as a measured 0.
pub fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        None
    } else {
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Fixed-capacity sliding window over timestamped counts — the Monitor's
/// per-stage throughput estimator (§5.1). Since PR 7 this is the telemetry
/// [`crate::telemetry::RollingWindow`] (identical push/evict/rate
/// semantics), so monitor/lane demand windows and telemetry samplers share
/// one signal type that a `telemetry::Registry` can hand out as a shared
/// handle.
pub use crate::telemetry::window::RollingWindow as SlidingWindow;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 100.0), Some(4.0));
        assert_eq!(percentile(&xs, 50.0), Some(2.5));
        assert_eq!(percentile(&[], 50.0), None);
    }

    #[test]
    fn percentile_unsorted_input() {
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), Some(2.0));
    }

    #[test]
    fn percentile_known_quantiles_five_elements() {
        // numpy.percentile([10,20,30,40,50], q) reference values.
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), Some(10.0));
        assert_eq!(percentile(&xs, 25.0), Some(20.0));
        assert_eq!(percentile(&xs, 50.0), Some(30.0));
        assert_eq!(percentile(&xs, 95.0), Some(48.0)); // 0.95*4=3.8 → 40+0.8*10
        assert_eq!(percentile(&xs, 100.0), Some(50.0));
    }

    #[test]
    fn percentile_single_element_is_constant() {
        for q in [0.0, 37.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&[7.5], q), Some(7.5));
            assert_eq!(percentile_sorted(&[7.5], q), 7.5);
        }
    }

    #[test]
    fn percentile_two_element_interpolation() {
        let xs = [10.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), Some(10.0));
        assert_eq!(percentile(&xs, 50.0), Some(20.0));
        assert_eq!(percentile(&xs, 95.0), Some(29.0)); // 10 + 0.95*20
        assert_eq!(percentile(&xs, 100.0), Some(30.0));
    }

    #[test]
    fn percentile_sorted_matches_percentile() {
        let mut xs = vec![5.0, 1.0, 9.0, 3.0, 7.0, 2.0];
        let unsorted = xs.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.0, 10.0, 50.0, 90.0, 95.0, 100.0] {
            assert_eq!(percentile(&unsorted, q), Some(percentile_sorted(&xs, q)));
        }
    }

    #[test]
    fn mean_is_none_on_empty() {
        assert_eq!(mean(&[]), None);
        assert_eq!(mean(&[4.0]), Some(4.0));
        assert_eq!(mean(&[1.0, 2.0, 3.0]), Some(2.0));
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 2.0, 8.0, 5.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn sliding_window_evicts() {
        let mut w = SlidingWindow::new(1000.0);
        w.push(0.0, 1.0);
        w.push(700.0, 1.0);
        w.push(1600.0, 1.0);
        assert_eq!(w.len(), 2); // t=0 evicted by t=1600, t=700 retained
        assert!((w.rate_per_sec(1600.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sliding_window_rate() {
        let mut w = SlidingWindow::new(2000.0);
        for i in 0..10 {
            w.push(i as f64 * 100.0, 1.0);
        }
        assert!((w.rate_per_sec(900.0) - 5.0).abs() < 1e-9);
    }
}
