//! Workload trace generators (§8.1, Table 5, Appendix D.1, Figure 9):
//! Steady (light/medium/heavy), Dynamic (interleaved steady mixes), and
//! Proprietary (synthetic diurnal/tidal trace reproducing the published
//! pattern shape — DESIGN.md §1 substitution).

use crate::config::PipelineSpec;
use crate::profiler::Profile;
use crate::request::Request;
use crate::util::Rng;

/// Workload family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    Light,
    Medium,
    Heavy,
    Dynamic,
    Proprietary,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 5] = [
        WorkloadKind::Light,
        WorkloadKind::Medium,
        WorkloadKind::Heavy,
        WorkloadKind::Dynamic,
        WorkloadKind::Proprietary,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            WorkloadKind::Light => "light",
            WorkloadKind::Medium => "medium",
            WorkloadKind::Heavy => "heavy",
            WorkloadKind::Dynamic => "dynamic",
            WorkloadKind::Proprietary => "proprietary",
        }
    }
}

/// Per-shape mix weights for a steady workload, following Table 5's
/// "k × {...}" compact-weight scheme: light favours the smallest shapes
/// (weight 2–3), medium the middle, heavy the largest.
pub fn steady_weights(p: &PipelineSpec, kind: WorkloadKind) -> Vec<f64> {
    let n = p.shapes.len();
    // Rank shapes by processing length.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| p.shapes[i].l_d);
    let mut w = vec![1.0; n];
    let third = n.div_ceil(3);
    match kind {
        WorkloadKind::Light => {
            for &i in order.iter().take(third) {
                w[i] = 2.0;
            }
        }
        WorkloadKind::Medium => {
            for &i in order.iter().skip(third).take(third) {
                w[i] = 2.0;
            }
        }
        WorkloadKind::Heavy => {
            for &i in order.iter().rev().take(third) {
                w[i] = 2.0;
            }
        }
        _ => {}
    }
    w
}

/// A generated trace: arrival-sorted requests.
#[derive(Clone, Debug)]
pub struct Trace {
    pub kind: WorkloadKind,
    pub requests: Vec<Request>,
    pub duration_ms: f64,
}

/// Per-request difficulty generator (the cascade router's synthetic input):
/// maps a uniform draw `u` and the arrival's horizon fraction `x` to a
/// difficulty in [0, 1]. Deterministic given the trace seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DifficultyModel {
    /// Uniform on [0, 1).
    Uniform,
    /// Mean difficulty drifts linearly from `from` at t=0 to `to` at the
    /// horizon (power transform `u^(1/m - 1)`, whose mean is `m`) — the
    /// regime change a static escalation threshold cannot track.
    Drift { from: f64, to: f64 },
}

impl DifficultyModel {
    /// Difficulty sample from uniform draw `u` at horizon fraction `x`.
    pub fn sample(&self, u: f64, x: f64) -> f64 {
        match *self {
            DifficultyModel::Uniform => u,
            DifficultyModel::Drift { from, to } => {
                let m = (from + (to - from) * x.clamp(0.0, 1.0)).clamp(0.05, 0.95);
                u.powf(1.0 / m - 1.0)
            }
        }
    }
}

/// Trace generator for one pipeline.
pub struct TraceGen<'a> {
    pub pipeline: &'a PipelineSpec,
    pub profile: &'a Profile,
    /// Arrival-rate multiplier over Table 5's per-model rate.
    pub rate_scale: f64,
    /// Per-request difficulty model (cascade routing input).
    pub difficulty: DifficultyModel,
}

impl<'a> TraceGen<'a> {
    pub fn new(pipeline: &'a PipelineSpec, profile: &'a Profile) -> Self {
        TraceGen { pipeline, profile, rate_scale: 1.0, difficulty: DifficultyModel::Uniform }
    }

    fn make_request(&self, id: u64, t_ms: f64, shape_idx: usize, difficulty: f64) -> Request {
        Request {
            id,
            pipeline_id: 0,
            shape_idx,
            arrival_ms: t_ms,
            deadline_ms: t_ms + self.profile.slo_ms[shape_idx],
            batch: 1,
            difficulty,
        }
    }

    /// Steady Poisson arrivals at the pipeline's rate for `duration_ms`.
    pub fn steady(&self, kind: WorkloadKind, duration_ms: f64, seed: u64) -> Trace {
        let weights = steady_weights(self.pipeline, kind);
        let rate = self.pipeline.rate_req_s * self.rate_scale;
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut reqs = Vec::new();
        let mut id = 0;
        loop {
            t += rng.exponential(rate) * 1000.0;
            if t >= duration_ms {
                break;
            }
            let shape = rng.categorical(&weights);
            let d = self.difficulty.sample(rng.f64(), t / duration_ms);
            reqs.push(self.make_request(id, t, shape, d));
            id += 1;
        }
        Trace { kind, requests: reqs, duration_ms }
    }

    /// Dynamic workload (Fig 9 left): the time span is divided into
    /// segments, each drawing from a randomly-chosen steady mix with a
    /// segment-specific rate tilt.
    pub fn dynamic(&self, duration_ms: f64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let segments = 6;
        let seg_ms = duration_ms / segments as f64;
        let mut reqs = Vec::new();
        let mut id = 0;
        let kinds = [WorkloadKind::Light, WorkloadKind::Medium, WorkloadKind::Heavy];
        for s in 0..segments {
            let kind = kinds[rng.below(3)];
            let weights = steady_weights(self.pipeline, kind);
            // Rate varies ±40% per segment.
            let rate = self.pipeline.rate_req_s * self.rate_scale * (0.6 + 0.8 * rng.f64());
            let mut t = s as f64 * seg_ms;
            let end = (s + 1) as f64 * seg_ms;
            loop {
                t += rng.exponential(rate) * 1000.0;
                if t >= end {
                    break;
                }
                let shape = rng.categorical(&weights);
                let d = self.difficulty.sample(rng.f64(), t / duration_ms);
                reqs.push(self.make_request(id, t, shape, d));
                id += 1;
            }
        }
        Trace { kind: WorkloadKind::Dynamic, requests: reqs, duration_ms }
    }

    /// Proprietary trace (Fig 9 right): two-peak diurnal/tidal intensity
    /// compressed into the horizon, rescaled so the total request count
    /// matches the corresponding Steady medium trace (Appendix D.1).
    pub fn proprietary(&self, duration_ms: f64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let weights = steady_weights(self.pipeline, WorkloadKind::Medium);
        let base = self.pipeline.rate_req_s * self.rate_scale;
        // Thinning: intensity(t) has a morning and an evening peak.
        let intensity = |t: f64| {
            let x = t / duration_ms; // 0..1 "day"
            let peak1 = (-((x - 0.35) / 0.10).powi(2)).exp();
            let peak2 = (-((x - 0.80) / 0.08).powi(2)).exp();
            0.35 + 1.1 * peak1 + 0.9 * peak2
        };
        let max_intensity = 1.45;
        let mut t = 0.0;
        let mut reqs = Vec::new();
        let mut id = 0;
        loop {
            t += rng.exponential(base * max_intensity) * 1000.0;
            if t >= duration_ms {
                break;
            }
            if rng.f64() < intensity(t) / max_intensity {
                let shape = rng.categorical(&weights);
                let d = self.difficulty.sample(rng.f64(), t / duration_ms);
                reqs.push(self.make_request(id, t, shape, d));
                id += 1;
            }
        }
        // Rescale count to match the steady medium trace (App D.1).
        let target = (base * duration_ms / 1000.0) as usize;
        if reqs.len() > target && target > 0 {
            let keep = target as f64 / reqs.len() as f64;
            let mut out = Vec::with_capacity(target);
            for r in reqs {
                if rng.f64() < keep {
                    out.push(r);
                }
            }
            reqs = out;
            for (i, r) in reqs.iter_mut().enumerate() {
                r.id = i as u64;
            }
        }
        Trace { kind: WorkloadKind::Proprietary, requests: reqs, duration_ms }
    }

    pub fn generate(&self, kind: WorkloadKind, duration_ms: f64, seed: u64) -> Trace {
        match kind {
            WorkloadKind::Dynamic => self.dynamic(duration_ms, seed),
            WorkloadKind::Proprietary => self.proprietary(duration_ms, seed),
            k => self.steady(k, duration_ms, seed),
        }
    }
}

// ---------------------------------------------------------------------------
// Mixed multi-pipeline traces (co-serving)
// ---------------------------------------------------------------------------

/// Time profile of one pipeline's arrival intensity over the trace horizon
/// (multiplies the pipeline's base rate). `Step` models a regime change —
/// the co-serving arbiter's raison d'être.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoadShape {
    /// Constant intensity 1.0.
    Flat,
    /// Intensity `before` until `at` (fraction of the horizon in [0,1]),
    /// then `after`.
    Step { at: f64, before: f64, after: f64 },
    /// Linear ramp from `from` at t=0 to `to` at the horizon.
    Ramp { from: f64, to: f64 },
}

impl LoadShape {
    /// Intensity multiplier at horizon fraction `x` in [0, 1].
    pub fn at(&self, x: f64) -> f64 {
        match *self {
            LoadShape::Flat => 1.0,
            LoadShape::Step { at, before, after } => {
                if x < at {
                    before
                } else {
                    after
                }
            }
            LoadShape::Ramp { from, to } => from + (to - from) * x.clamp(0.0, 1.0),
        }
    }

    fn max(&self) -> f64 {
        match *self {
            LoadShape::Flat => 1.0,
            LoadShape::Step { before, after, .. } => before.max(after),
            LoadShape::Ramp { from, to } => from.max(to),
        }
    }
}

/// One pipeline's slice of a mixed trace.
pub struct MixedSpec<'a> {
    pub pipeline: &'a PipelineSpec,
    pub profile: &'a Profile,
    /// Shape-mix family for this pipeline's requests.
    pub kind: WorkloadKind,
    /// Base arrival-rate multiplier over the pipeline's Table-5 rate.
    pub rate_scale: f64,
    /// Time-varying intensity on top of `rate_scale`.
    pub load: LoadShape,
    /// Per-request difficulty model (cascade routing input).
    pub difficulty: DifficultyModel,
}

/// A mixed trace: arrival-sorted requests tagged with `pipeline_id`, with
/// globally unique request ids.
#[derive(Clone, Debug)]
pub struct MixedTrace {
    pub requests: Vec<Request>,
    pub duration_ms: f64,
    pub n_pipelines: usize,
}

impl MixedTrace {
    /// Requests belonging to one pipeline, in arrival order.
    pub fn of_pipeline(&self, p: usize) -> impl Iterator<Item = &Request> {
        self.requests.iter().filter(move |r| r.pipeline_id == p)
    }
}

/// Generate a mixed multi-pipeline trace: each pipeline gets an independent
/// Poisson arrival process (thinned against its [`LoadShape`]) from a
/// decorrelated per-pipeline substream of `seed`; streams are then merged in
/// arrival order and re-id'd globally. Determinism: the same `(specs, seed)`
/// reproduce the identical trace, including per-request pipeline tags.
pub fn mixed(specs: &[MixedSpec], duration_ms: f64, seed: u64) -> MixedTrace {
    let mut all: Vec<Request> = Vec::new();
    for (p, spec) in specs.iter().enumerate() {
        // Per-pipeline substream: SplitMix-style decorrelation keeps each
        // pipeline's arrivals independent of how many co-tenants exist.
        let sub_seed = seed ^ (p as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(sub_seed);
        let weights = steady_weights(spec.pipeline, spec.kind);
        let base = spec.pipeline.rate_req_s * spec.rate_scale;
        let max_scale = spec.load.max();
        if base <= 0.0 || max_scale <= 0.0 {
            continue;
        }
        let mut t = 0.0;
        loop {
            t += rng.exponential(base * max_scale) * 1000.0;
            if t >= duration_ms {
                break;
            }
            // Thinning against the time-varying intensity.
            if rng.f64() >= spec.load.at(t / duration_ms) / max_scale {
                continue;
            }
            let shape_idx = rng.categorical(&weights);
            let difficulty = spec.difficulty.sample(rng.f64(), t / duration_ms);
            all.push(Request {
                id: 0, // assigned after the merge
                pipeline_id: p,
                shape_idx,
                arrival_ms: t,
                deadline_ms: t + spec.profile.slo_ms[shape_idx],
                batch: 1,
                difficulty,
            });
        }
    }
    // Merge: total order on (arrival, pipeline) — arrivals within one
    // pipeline are already strictly increasing, so this is deterministic.
    all.sort_by(|a, b| {
        a.arrival_ms
            .partial_cmp(&b.arrival_ms)
            .unwrap()
            .then(a.pipeline_id.cmp(&b.pipeline_id))
    });
    for (i, r) in all.iter_mut().enumerate() {
        r.id = i as u64;
    }
    MixedTrace { requests: all, duration_ms, n_pipelines: specs.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, SolverConstants};
    use crate::perfmodel::PerfModel;

    fn gen(p: &PipelineSpec) -> (Profile, SolverConstants) {
        let c = SolverConstants::default();
        (Profile::build(&PerfModel::new(ClusterSpec::l20_128()), p, &c), c)
    }

    #[test]
    fn steady_rate_is_approximately_right() {
        let p = PipelineSpec::sd3(); // 20 req/s
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let t = tg.steady(WorkloadKind::Medium, 60_000.0, 1);
        let rate = t.requests.len() as f64 / 60.0;
        assert!((rate - 20.0).abs() < 3.0, "rate {rate}");
    }

    #[test]
    fn arrivals_sorted_and_within_horizon() {
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        for kind in WorkloadKind::ALL {
            let t = tg.generate(kind, 120_000.0, 7);
            let mut prev = 0.0;
            for r in &t.requests {
                assert!(r.arrival_ms >= prev, "{kind:?} unsorted");
                assert!(r.arrival_ms < t.duration_ms);
                assert!(r.deadline_ms > r.arrival_ms);
                prev = r.arrival_ms;
            }
            assert!(!t.requests.is_empty(), "{kind:?} empty");
        }
    }

    #[test]
    fn heavy_mix_skews_to_large_shapes() {
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let mean_l = |t: &Trace| {
            t.requests.iter().map(|r| p.shapes[r.shape_idx].l_d as f64).sum::<f64>()
                / t.requests.len() as f64
        };
        let light = tg.steady(WorkloadKind::Light, 300_000.0, 3);
        let heavy = tg.steady(WorkloadKind::Heavy, 300_000.0, 3);
        assert!(
            mean_l(&heavy) > 1.3 * mean_l(&light),
            "heavy {} !>> light {}",
            mean_l(&heavy),
            mean_l(&light)
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let p = PipelineSpec::cogvideo();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let a = tg.dynamic(100_000.0, 9);
        let b = tg.dynamic(100_000.0, 9);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.shape_idx, y.shape_idx);
        }
    }

    #[test]
    fn proprietary_has_tidal_structure() {
        let p = PipelineSpec::sd3();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let t = tg.proprietary(600_000.0, 11);
        // Peak span (around 35% of the day) must be busier than the trough
        // (around 5%).
        let count_in = |lo: f64, hi: f64| {
            t.requests
                .iter()
                .filter(|r| r.arrival_ms >= lo * 600_000.0 && r.arrival_ms < hi * 600_000.0)
                .count() as f64
        };
        let peak = count_in(0.30, 0.40);
        let trough = count_in(0.0, 0.10);
        assert!(peak > 1.5 * trough, "peak {peak} trough {trough}");
    }

    #[test]
    fn every_kind_is_deterministic_per_seed() {
        // Same seed ⇒ byte-identical trace (arrival times, shapes, ids,
        // deadlines) for every workload family.
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        for kind in WorkloadKind::ALL {
            let a = tg.generate(kind, 150_000.0, 21);
            let b = tg.generate(kind, 150_000.0, 21);
            assert_eq!(a.requests.len(), b.requests.len(), "{kind:?}");
            for (x, y) in a.requests.iter().zip(&b.requests) {
                assert_eq!(x.id, y.id, "{kind:?}");
                assert_eq!(x.arrival_ms, y.arrival_ms, "{kind:?}");
                assert_eq!(x.shape_idx, y.shape_idx, "{kind:?}");
                assert_eq!(x.deadline_ms, y.deadline_ms, "{kind:?}");
                assert_eq!(x.pipeline_id, 0, "{kind:?}");
            }
            // A different seed must produce a different trace.
            let c = tg.generate(kind, 150_000.0, 22);
            let same = a.requests.len() == c.requests.len()
                && a.requests
                    .iter()
                    .zip(&c.requests)
                    .all(|(x, y)| x.arrival_ms == y.arrival_ms);
            assert!(!same, "{kind:?}: seeds 21 and 22 gave identical traces");
        }
    }

    fn mixed_fixture() -> (PipelineSpec, Profile, PipelineSpec, Profile) {
        let sd3 = PipelineSpec::sd3();
        let (sd3_prof, _) = gen(&sd3);
        let flux = PipelineSpec::flux();
        let (flux_prof, _) = gen(&flux);
        (sd3, sd3_prof, flux, flux_prof)
    }

    fn mixed_specs<'a>(
        sd3: &'a PipelineSpec,
        sd3_prof: &'a Profile,
        flux: &'a PipelineSpec,
        flux_prof: &'a Profile,
    ) -> Vec<MixedSpec<'a>> {
        vec![
            MixedSpec {
                pipeline: sd3,
                profile: sd3_prof,
                kind: WorkloadKind::Medium,
                rate_scale: 0.5,
                load: LoadShape::Step { at: 0.5, before: 1.0, after: 0.3 },
                difficulty: DifficultyModel::Uniform,
            },
            MixedSpec {
                pipeline: flux,
                profile: flux_prof,
                kind: WorkloadKind::Medium,
                rate_scale: 1.0,
                load: LoadShape::Ramp { from: 0.5, to: 1.5 },
                difficulty: DifficultyModel::Uniform,
            },
        ]
    }

    #[test]
    fn mixed_trace_is_deterministic_per_seed() {
        let (sd3, sd3_prof, flux, flux_prof) = mixed_fixture();
        let specs = mixed_specs(&sd3, &sd3_prof, &flux, &flux_prof);
        let a = mixed(&specs, 300_000.0, 13);
        let b = mixed(&specs, 300_000.0, 13);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.pipeline_id, y.pipeline_id);
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.shape_idx, y.shape_idx);
            assert_eq!(x.deadline_ms, y.deadline_ms);
            assert_eq!(x.difficulty, y.difficulty);
        }
    }

    #[test]
    fn mixed_trace_interleaves_and_tags_pipelines() {
        let (sd3, sd3_prof, flux, flux_prof) = mixed_fixture();
        let specs = mixed_specs(&sd3, &sd3_prof, &flux, &flux_prof);
        let t = mixed(&specs, 300_000.0, 7);
        assert_eq!(t.n_pipelines, 2);
        let n0 = t.of_pipeline(0).count();
        let n1 = t.of_pipeline(1).count();
        assert!(n0 > 0 && n1 > 0, "both pipelines must contribute ({n0}/{n1})");
        assert_eq!(n0 + n1, t.requests.len());
        // Globally sorted, globally unique sequential ids.
        let mut prev = 0.0;
        for (i, r) in t.requests.iter().enumerate() {
            assert!(r.arrival_ms >= prev);
            assert_eq!(r.id, i as u64);
            assert!(r.deadline_ms > r.arrival_ms);
            prev = r.arrival_ms;
        }
        // Each pipeline's substream is unaffected by the other's presence:
        // shape indices stay inside each pipeline's own shape table.
        for r in t.of_pipeline(0) {
            assert!(r.shape_idx < sd3.shapes.len());
        }
        for r in t.of_pipeline(1) {
            assert!(r.shape_idx < flux.shapes.len());
        }
    }

    #[test]
    fn load_step_shifts_volume_across_halves() {
        let (sd3, sd3_prof, flux, flux_prof) = mixed_fixture();
        let specs = mixed_specs(&sd3, &sd3_prof, &flux, &flux_prof);
        let t = mixed(&specs, 600_000.0, 3);
        let half = 300_000.0;
        let sd3_first = t.of_pipeline(0).filter(|r| r.arrival_ms < half).count() as f64;
        let sd3_second = t.of_pipeline(0).filter(|r| r.arrival_ms >= half).count() as f64;
        // Step 1.0 -> 0.3: the second half must carry well under half the load.
        assert!(
            sd3_second < 0.6 * sd3_first,
            "step down not visible: {sd3_first} vs {sd3_second}"
        );
        let flux_first = t.of_pipeline(1).filter(|r| r.arrival_ms < half).count() as f64;
        let flux_second = t.of_pipeline(1).filter(|r| r.arrival_ms >= half).count() as f64;
        // Ramp 0.5 -> 1.5: second half busier.
        assert!(
            flux_second > 1.2 * flux_first,
            "ramp up not visible: {flux_first} vs {flux_second}"
        );
    }

    #[test]
    fn load_shape_intensity_math() {
        assert_eq!(LoadShape::Flat.at(0.7), 1.0);
        let s = LoadShape::Step { at: 0.5, before: 2.0, after: 0.5 };
        assert_eq!(s.at(0.49), 2.0);
        assert_eq!(s.at(0.5), 0.5);
        let r = LoadShape::Ramp { from: 1.0, to: 3.0 };
        assert!((r.at(0.5) - 2.0).abs() < 1e-12);
        assert!((r.at(0.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn load_shape_boundary_behavior() {
        // Horizon endpoints for every variant.
        assert_eq!(LoadShape::Flat.at(0.0), 1.0);
        assert_eq!(LoadShape::Flat.at(1.0), 1.0);
        let r = LoadShape::Ramp { from: 1.0, to: 3.0 };
        assert_eq!(r.at(0.0), 1.0);
        assert_eq!(r.at(1.0), 3.0);
        // Ramp clamps outside [0, 1] (generators only query inside, but
        // callers plotting shapes may not).
        assert_eq!(r.at(-0.5), 1.0);
        assert_eq!(r.at(1.5), 3.0);
        // Step switches exactly at its breakpoint (x < at keeps `before`),
        // including degenerate breakpoints at the horizon endpoints.
        let s0 = LoadShape::Step { at: 0.0, before: 2.0, after: 0.5 };
        assert_eq!(s0.at(0.0), 0.5, "at=0: `after` governs the whole trace");
        assert_eq!(s0.at(1.0), 0.5);
        let s1 = LoadShape::Step { at: 1.0, before: 2.0, after: 0.5 };
        assert_eq!(s1.at(0.999), 2.0, "at=1: `before` governs the whole trace");
        assert_eq!(s1.at(1.0), 0.5, "the breakpoint itself flips to `after`");
        assert_eq!(s1.at(2.0), 0.5);
    }

    #[test]
    fn difficulty_model_math_and_drift() {
        // Uniform passes the draw through; endpoints preserved.
        assert_eq!(DifficultyModel::Uniform.sample(0.3, 0.9), 0.3);
        assert_eq!(DifficultyModel::Uniform.sample(0.0, 0.0), 0.0);
        // Drift: empirical mean tracks the drifting target at both ends.
        let d = DifficultyModel::Drift { from: 0.2, to: 0.8 };
        let mut rng = Rng::new(42);
        for (x, want) in [(0.0, 0.2), (1.0, 0.8)] {
            let n = 4000;
            let mean: f64 = (0..n).map(|_| d.sample(rng.f64(), x)).sum::<f64>() / n as f64;
            assert!((mean - want).abs() < 0.03, "x={x}: mean {mean} want {want}");
        }
        // Samples stay in [0, 1].
        for _ in 0..1000 {
            let v = d.sample(rng.f64(), rng.f64());
            assert!((0.0..=1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn traces_carry_seeded_difficulty() {
        let p = PipelineSpec::sd3();
        let (profile, _) = gen(&p);
        let mut tg = TraceGen::new(&p, &profile);
        tg.difficulty = DifficultyModel::Drift { from: 0.25, to: 0.75 };
        let a = tg.steady(WorkloadKind::Medium, 300_000.0, 17);
        let b = tg.steady(WorkloadKind::Medium, 300_000.0, 17);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.difficulty, y.difficulty);
            assert!((0.0..=1.0).contains(&x.difficulty));
        }
        // Drift visible end-to-end: the last third is harder than the first.
        let third = 100_000.0;
        let mean_in = |lo: f64, hi: f64| {
            let xs: Vec<f64> = a
                .requests
                .iter()
                .filter(|r| r.arrival_ms >= lo && r.arrival_ms < hi)
                .map(|r| r.difficulty)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let early = mean_in(0.0, third);
        let late = mean_in(2.0 * third, 3.0 * third);
        assert!(late > early + 0.2, "drift not visible: early {early} late {late}");
    }

    #[test]
    fn rate_scale_scales_volume() {
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let mut tg = TraceGen::new(&p, &profile);
        let base = tg.steady(WorkloadKind::Medium, 300_000.0, 5).requests.len();
        tg.rate_scale = 2.0;
        let doubled = tg.steady(WorkloadKind::Medium, 300_000.0, 5).requests.len();
        assert!((doubled as f64 / base as f64 - 2.0).abs() < 0.3);
    }
}
