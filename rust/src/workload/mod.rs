//! Workload trace generators (§8.1, Table 5, Appendix D.1, Figure 9):
//! Steady (light/medium/heavy), Dynamic (interleaved steady mixes), and
//! Proprietary (synthetic diurnal/tidal trace reproducing the published
//! pattern shape — DESIGN.md §1 substitution).

use crate::config::PipelineSpec;
use crate::profiler::Profile;
use crate::request::Request;
use crate::util::Rng;

/// Workload family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    Light,
    Medium,
    Heavy,
    Dynamic,
    Proprietary,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 5] = [
        WorkloadKind::Light,
        WorkloadKind::Medium,
        WorkloadKind::Heavy,
        WorkloadKind::Dynamic,
        WorkloadKind::Proprietary,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            WorkloadKind::Light => "light",
            WorkloadKind::Medium => "medium",
            WorkloadKind::Heavy => "heavy",
            WorkloadKind::Dynamic => "dynamic",
            WorkloadKind::Proprietary => "proprietary",
        }
    }
}

/// Per-shape mix weights for a steady workload, following Table 5's
/// "k × {...}" compact-weight scheme: light favours the smallest shapes
/// (weight 2–3), medium the middle, heavy the largest.
pub fn steady_weights(p: &PipelineSpec, kind: WorkloadKind) -> Vec<f64> {
    let n = p.shapes.len();
    // Rank shapes by processing length.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| p.shapes[i].l_d);
    let mut w = vec![1.0; n];
    let third = n.div_ceil(3);
    match kind {
        WorkloadKind::Light => {
            for &i in order.iter().take(third) {
                w[i] = 2.0;
            }
        }
        WorkloadKind::Medium => {
            for &i in order.iter().skip(third).take(third) {
                w[i] = 2.0;
            }
        }
        WorkloadKind::Heavy => {
            for &i in order.iter().rev().take(third) {
                w[i] = 2.0;
            }
        }
        _ => {}
    }
    w
}

/// A generated trace: arrival-sorted requests.
#[derive(Clone, Debug)]
pub struct Trace {
    pub kind: WorkloadKind,
    pub requests: Vec<Request>,
    pub duration_ms: f64,
}

/// Trace generator for one pipeline.
pub struct TraceGen<'a> {
    pub pipeline: &'a PipelineSpec,
    pub profile: &'a Profile,
    /// Arrival-rate multiplier over Table 5's per-model rate.
    pub rate_scale: f64,
}

impl<'a> TraceGen<'a> {
    pub fn new(pipeline: &'a PipelineSpec, profile: &'a Profile) -> Self {
        TraceGen { pipeline, profile, rate_scale: 1.0 }
    }

    fn make_request(&self, id: u64, t_ms: f64, shape_idx: usize) -> Request {
        Request {
            id,
            shape_idx,
            arrival_ms: t_ms,
            deadline_ms: t_ms + self.profile.slo_ms[shape_idx],
            batch: 1,
        }
    }

    /// Steady Poisson arrivals at the pipeline's rate for `duration_ms`.
    pub fn steady(&self, kind: WorkloadKind, duration_ms: f64, seed: u64) -> Trace {
        let weights = steady_weights(self.pipeline, kind);
        let rate = self.pipeline.rate_req_s * self.rate_scale;
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut reqs = Vec::new();
        let mut id = 0;
        loop {
            t += rng.exponential(rate) * 1000.0;
            if t >= duration_ms {
                break;
            }
            let shape = rng.categorical(&weights);
            reqs.push(self.make_request(id, t, shape));
            id += 1;
        }
        Trace { kind, requests: reqs, duration_ms }
    }

    /// Dynamic workload (Fig 9 left): the time span is divided into
    /// segments, each drawing from a randomly-chosen steady mix with a
    /// segment-specific rate tilt.
    pub fn dynamic(&self, duration_ms: f64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let segments = 6;
        let seg_ms = duration_ms / segments as f64;
        let mut reqs = Vec::new();
        let mut id = 0;
        let kinds = [WorkloadKind::Light, WorkloadKind::Medium, WorkloadKind::Heavy];
        for s in 0..segments {
            let kind = kinds[rng.below(3)];
            let weights = steady_weights(self.pipeline, kind);
            // Rate varies ±40% per segment.
            let rate = self.pipeline.rate_req_s * self.rate_scale * (0.6 + 0.8 * rng.f64());
            let mut t = s as f64 * seg_ms;
            let end = (s + 1) as f64 * seg_ms;
            loop {
                t += rng.exponential(rate) * 1000.0;
                if t >= end {
                    break;
                }
                reqs.push(self.make_request(id, t, rng.categorical(&weights)));
                id += 1;
            }
        }
        Trace { kind: WorkloadKind::Dynamic, requests: reqs, duration_ms }
    }

    /// Proprietary trace (Fig 9 right): two-peak diurnal/tidal intensity
    /// compressed into the horizon, rescaled so the total request count
    /// matches the corresponding Steady medium trace (Appendix D.1).
    pub fn proprietary(&self, duration_ms: f64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let weights = steady_weights(self.pipeline, WorkloadKind::Medium);
        let base = self.pipeline.rate_req_s * self.rate_scale;
        // Thinning: intensity(t) has a morning and an evening peak.
        let intensity = |t: f64| {
            let x = t / duration_ms; // 0..1 "day"
            let peak1 = (-((x - 0.35) / 0.10).powi(2)).exp();
            let peak2 = (-((x - 0.80) / 0.08).powi(2)).exp();
            0.35 + 1.1 * peak1 + 0.9 * peak2
        };
        let max_intensity = 1.45;
        let mut t = 0.0;
        let mut reqs = Vec::new();
        let mut id = 0;
        loop {
            t += rng.exponential(base * max_intensity) * 1000.0;
            if t >= duration_ms {
                break;
            }
            if rng.f64() < intensity(t) / max_intensity {
                reqs.push(self.make_request(id, t, rng.categorical(&weights)));
                id += 1;
            }
        }
        // Rescale count to match the steady medium trace (App D.1).
        let target = (base * duration_ms / 1000.0) as usize;
        if reqs.len() > target && target > 0 {
            let keep = target as f64 / reqs.len() as f64;
            let mut out = Vec::with_capacity(target);
            for r in reqs {
                if rng.f64() < keep {
                    out.push(r);
                }
            }
            reqs = out;
            for (i, r) in reqs.iter_mut().enumerate() {
                r.id = i as u64;
            }
        }
        Trace { kind: WorkloadKind::Proprietary, requests: reqs, duration_ms }
    }

    pub fn generate(&self, kind: WorkloadKind, duration_ms: f64, seed: u64) -> Trace {
        match kind {
            WorkloadKind::Dynamic => self.dynamic(duration_ms, seed),
            WorkloadKind::Proprietary => self.proprietary(duration_ms, seed),
            k => self.steady(k, duration_ms, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, SolverConstants};
    use crate::perfmodel::PerfModel;

    fn gen(p: &PipelineSpec) -> (Profile, SolverConstants) {
        let c = SolverConstants::default();
        (Profile::build(&PerfModel::new(ClusterSpec::l20_128()), p, &c), c)
    }

    #[test]
    fn steady_rate_is_approximately_right() {
        let p = PipelineSpec::sd3(); // 20 req/s
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let t = tg.steady(WorkloadKind::Medium, 60_000.0, 1);
        let rate = t.requests.len() as f64 / 60.0;
        assert!((rate - 20.0).abs() < 3.0, "rate {rate}");
    }

    #[test]
    fn arrivals_sorted_and_within_horizon() {
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        for kind in WorkloadKind::ALL {
            let t = tg.generate(kind, 120_000.0, 7);
            let mut prev = 0.0;
            for r in &t.requests {
                assert!(r.arrival_ms >= prev, "{kind:?} unsorted");
                assert!(r.arrival_ms < t.duration_ms);
                assert!(r.deadline_ms > r.arrival_ms);
                prev = r.arrival_ms;
            }
            assert!(!t.requests.is_empty(), "{kind:?} empty");
        }
    }

    #[test]
    fn heavy_mix_skews_to_large_shapes() {
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let mean_l = |t: &Trace| {
            t.requests.iter().map(|r| p.shapes[r.shape_idx].l_d as f64).sum::<f64>()
                / t.requests.len() as f64
        };
        let light = tg.steady(WorkloadKind::Light, 300_000.0, 3);
        let heavy = tg.steady(WorkloadKind::Heavy, 300_000.0, 3);
        assert!(
            mean_l(&heavy) > 1.3 * mean_l(&light),
            "heavy {} !>> light {}",
            mean_l(&heavy),
            mean_l(&light)
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let p = PipelineSpec::cogvideo();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let a = tg.dynamic(100_000.0, 9);
        let b = tg.dynamic(100_000.0, 9);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.shape_idx, y.shape_idx);
        }
    }

    #[test]
    fn proprietary_has_tidal_structure() {
        let p = PipelineSpec::sd3();
        let (profile, _) = gen(&p);
        let tg = TraceGen::new(&p, &profile);
        let t = tg.proprietary(600_000.0, 11);
        // Peak span (around 35% of the day) must be busier than the trough
        // (around 5%).
        let count_in = |lo: f64, hi: f64| {
            t.requests
                .iter()
                .filter(|r| r.arrival_ms >= lo * 600_000.0 && r.arrival_ms < hi * 600_000.0)
                .count() as f64
        };
        let peak = count_in(0.30, 0.40);
        let trough = count_in(0.0, 0.10);
        assert!(peak > 1.5 * trough, "peak {peak} trough {trough}");
    }

    #[test]
    fn rate_scale_scales_volume() {
        let p = PipelineSpec::flux();
        let (profile, _) = gen(&p);
        let mut tg = TraceGen::new(&p, &profile);
        let base = tg.steady(WorkloadKind::Medium, 300_000.0, 5).requests.len();
        tg.rate_scale = 2.0;
        let doubled = tg.steady(WorkloadKind::Medium, 300_000.0, 5).requests.len();
        assert!((doubled as f64 / base as f64 - 2.0).abs() < 0.3);
    }
}
