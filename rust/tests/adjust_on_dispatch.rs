//! Property tests for Adjust-on-Dispatch (§5.3) and engine safety under
//! placement-switch storms: random interleavings of switches, dispatches
//! and completions must never lose requests, double-book GPUs, leak
//! activation memory, or leave a plan unservable.

use tridentserve::cluster::Topology;
use tridentserve::config::{ClusterSpec, PipelineSpec, SolverConstants, Stage};
use tridentserve::dispatch::{RequestPlans, StagePlan};
use tridentserve::engine::{Engine, PlanState, StageExec};
use tridentserve::perfmodel::PerfModel;
use tridentserve::placement::{Pi, PlacementPlan};
use tridentserve::profiler::Profile;
use tridentserve::util::prop::run_prop;
use tridentserve::util::Rng;

struct FixedExec(f64);
impl StageExec for FixedExec {
    fn exec_ms(&mut self, _: usize, _: Stage, _: usize, _: usize) -> f64 {
        self.0
    }
}

fn fixture() -> (PipelineSpec, Profile, Topology) {
    let p = PipelineSpec::sd3();
    let cluster = ClusterSpec::tiny(2, 8);
    let profile =
        Profile::build(&PerfModel::new(cluster.clone()), &p, &SolverConstants::default());
    (p, profile, Topology::new(cluster))
}

fn random_placement(rng: &mut Rng, g: usize) -> PlacementPlan {
    let pi = (0..g)
        .map(|_| Pi::ALL[rng.below(Pi::ALL.len())])
        .collect();
    PlacementPlan { pi }
}

fn colocated_plan(req: u64, shape_idx: usize, gpus: Vec<usize>) -> RequestPlans {
    let k = gpus.len();
    RequestPlans {
        req,
        shape_idx,
        vr_type: 0,
        e: StagePlan { req, stage: Stage::Encode, gpus: gpus.clone(), degree: k },
        d: StagePlan { req, stage: Stage::Diffuse, gpus: gpus.clone(), degree: k },
        c: StagePlan { req, stage: Stage::Decode, gpus, degree: k },
        e_merged: true,
        c_on_subset: true,
        profit: 0.0,
    }
}

#[test]
fn prop_switch_storm_conserves_requests() {
    let (_p, profile, topo) = fixture();
    run_prop(0xA0D, 30, |rng: &mut Rng, _| {
        let g = topo.total_gpus();
        let mut engine = Engine::new(topo.clone(), random_placement(rng, g), &profile);
        let mut exec = FixedExec(10.0);
        let mut now = 0.0;
        let mut enqueued = 0u64;
        let mut inflight: Vec<(usize, f64)> = Vec::new(); // (plan, finish)

        for step in 0..120 {
            match rng.below(4) {
                // Random placement switch (metadata-only).
                0 => engine.apply_switch(random_placement(rng, g)),
                // Enqueue a small colocated request on a random single GPU.
                1 => {
                    let gpu = rng.below(g);
                    engine.enqueue(&colocated_plan(enqueued, 0, vec![gpu]), &profile);
                    enqueued += 1;
                }
                // Advance time + start whatever can start.
                _ => {
                    now += 5.0 + rng.f64() * 20.0;
                    // Complete everything that finished.
                    inflight.retain(|&(pid, fin)| {
                        if fin <= now {
                            engine.complete(pid, fin, 0.0, None);
                            false
                        } else {
                            true
                        }
                    });
                    for sp in engine.advance(now, &mut exec, &profile) {
                        inflight.push((sp.plan, sp.finish_ms));
                    }
                }
            }
            let _ = step;
        }
        // Drain.
        for _ in 0..1000 {
            if inflight.is_empty() {
                let started = engine.advance(now, &mut exec, &profile);
                if started.is_empty() {
                    break;
                }
                for sp in started {
                    inflight.push((sp.plan, sp.finish_ms));
                }
            }
            inflight.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            if let Some((pid, fin)) = inflight.first().copied() {
                now = now.max(fin);
                engine.complete(pid, fin, 0.0, None);
                inflight.remove(0);
            }
        }

        // Conservation: every plan is Done or Cancelled; none stuck.
        let stuck = engine
            .plans
            .iter()
            .filter(|p| matches!(p.state, PlanState::Waiting | PlanState::Running))
            .count();
        assert_eq!(stuck, 0, "{stuck} plans stuck after drain");
        // Done + OOM-cancelled requests account for everything enqueued.
        let done: std::collections::HashSet<u64> = engine
            .plans
            .iter()
            .filter(|p| p.state == PlanState::Done)
            .map(|p| p.req)
            .collect();
        let oomed: std::collections::HashSet<u64> =
            engine.ooms.iter().map(|o| o.req).collect();
        assert_eq!(
            done.len() + oomed.len(),
            enqueued as usize,
            "requests lost: {} done, {} oomed, {} enqueued",
            done.len(),
            oomed.len(),
            enqueued
        );
        // No activation leak: all reservations released.
        for gpu in 0..g {
            assert!(
                engine.vram.gpu(gpu).act_gb.abs() < 1e-9,
                "gpu {gpu} leaked {} GB act",
                engine.vram.gpu(gpu).act_gb
            );
        }
    });
}

#[test]
fn prop_no_gpu_runs_two_plans() {
    let (_p, profile, topo) = fixture();
    run_prop(0xA0E, 20, |rng: &mut Rng, _| {
        let g = topo.total_gpus();
        let mut engine = Engine::new(topo.clone(), PlacementPlan::uniform(g, Pi::Edc), &profile);
        let mut exec = FixedExec(50.0);
        // Saturate with overlapping multi-GPU plans.
        for req in 0..40u64 {
            let node = rng.below(2);
            let k = [1, 2, 4][rng.below(3)];
            let start = node * 8 + rng.below(8 - k + 1);
            let gpus: Vec<usize> = (start..start + k).collect();
            engine.enqueue(&colocated_plan(req, 0, gpus), &profile);
        }
        let started = engine.advance(0.0, &mut exec, &profile);
        // Check pairwise disjointness of running plans' GPU sets.
        let mut owner = vec![None; g];
        for sp in &started {
            for &gpu in &engine.plans[sp.plan].gpus {
                assert!(
                    owner[gpu].is_none(),
                    "gpu {gpu} owned by {:?} and {}",
                    owner[gpu],
                    sp.plan
                );
                owner[gpu] = Some(sp.plan);
            }
        }
    });
}

#[test]
fn switch_preserves_fifo_of_inflight_plans() {
    // Plans enqueued before a switch must complete under their original
    // assignment (§5.3 safety argument).
    let (_p, profile, topo) = fixture();
    let g = topo.total_gpus();
    let mut engine = Engine::new(topo, PlacementPlan::uniform(g, Pi::Edc), &profile);
    let mut exec = FixedExec(100.0);
    engine.enqueue(&colocated_plan(1, 0, vec![0]), &profile);
    let started = engine.advance(0.0, &mut exec, &profile);
    assert_eq!(started.len(), 1);
    // Switch mid-flight.
    engine.apply_switch(PlacementPlan::uniform(g, Pi::E));
    // The running plan still completes normally on its GPUs.
    let fin = started[0].finish_ms;
    engine.complete(started[0].plan, fin, 0.0, None);
    assert_eq!(engine.plans[started[0].plan].state, PlanState::Done);
    // A post-switch plan on the same GPU must lazily reload what it needs.
    let loads_before = engine.adjust_loads;
    engine.apply_switch(PlacementPlan::uniform(g, Pi::Edc));
    engine.enqueue(&colocated_plan(2, 0, vec![0]), &profile);
    let started = engine.advance(fin, &mut exec, &profile);
    assert_eq!(started.len(), 1);
    let _ = loads_before; // loads may be zero if replicas were never evicted
}
