//! Cascade integration: a logical request stream served as a
//! turbo/full-pipeline cascade end-to-end. Pins the conservation contract —
//! every logical request is delivered exactly once, every escalation is
//! served exactly once on exactly one variant — across escalations *and*
//! cluster re-arbitrations, plus the adaptive controller's quality floor.

use std::collections::{BTreeSet, HashSet};

use tridentserve::cascade::{
    calibrate_threshold, run_cascade, CascadeReport, QualityModel, RouterMode,
    ThresholdController, CHEAP_LANE, ESC_BIT, HEAVY_LANE,
};
use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    ArbiterPolicy, ClusterArbiter, CoServeConfig, LaneSignal, PipelineSetup, ResizePolicy,
};
use tridentserve::request::Outcome;
use tridentserve::workload::{DifficultyModel, Trace, TraceGen, WorkloadKind};

const DURATION_MS: f64 = 240_000.0;

fn setups(cluster: &ClusterSpec) -> (PipelineSetup, PipelineSetup) {
    (PipelineSetup::new("sd3-turbo", cluster), PipelineSetup::new("sd3", cluster))
}

fn logical_trace(heavy: &PipelineSetup, difficulty: DifficultyModel, seed: u64) -> Trace {
    let mut tg = TraceGen::new(&heavy.pipeline, &heavy.profile);
    tg.rate_scale = 0.15; // ~3 req/s on a 32-GPU cluster: moderate load
    tg.difficulty = difficulty;
    tg.steady(WorkloadKind::Medium, DURATION_MS, seed)
}

fn cfg(seed: u64) -> CoServeConfig {
    CoServeConfig { seed, monitor_ms: 2_000.0, ..Default::default() }
}

/// Test arbiter that deterministically forces one node move mid-run (on top
/// of the ILP bootstrap), so conservation is always exercised across a
/// drain-then-reassign handoff regardless of organic trigger timing.
struct ForcedSwap {
    inner: ClusterArbiter,
    at_ms: f64,
    fired: bool,
}

impl ArbiterPolicy for ForcedSwap {
    fn name(&self) -> String {
        "forced-swap".into()
    }

    fn initial(&mut self, signals: &[LaneSignal], total_nodes: usize) -> Vec<usize> {
        self.inner.initial(signals, total_nodes)
    }

    fn rearbitrate(
        &mut self,
        now_ms: f64,
        _signals: &[LaneSignal],
        current: &[usize],
        _total_nodes: usize,
    ) -> Option<Vec<usize>> {
        if self.fired || now_ms < self.at_ms {
            return None;
        }
        let mut out = current.to_vec();
        let hi = (0..out.len()).max_by_key(|&i| out[i])?;
        let lo = (0..out.len()).min_by_key(|&i| out[i])?;
        if hi == lo || out[hi] <= 1 {
            return None;
        }
        out[hi] -= 1;
        out[lo] += 1;
        self.fired = true;
        Some(out)
    }
}

/// The conservation contract, checked against the generating trace:
/// * the cheap lane saw every trace request except the direct-routed ones,
///   each exactly once;
/// * the heavy lane saw exactly the escalations (tagged with `ESC_BIT`,
///   each descending from a cheap-completed request) plus the
///   direct-routed arrivals (untagged), each exactly once;
/// * escalated and direct-routed sets are disjoint;
/// * the logical roll-up covers every trace request exactly once.
fn assert_conservation(report: &CascadeReport, trace: &Trace) {
    let trace_ids: HashSet<u64> = trace.requests.iter().map(|r| r.id).collect();
    assert!(
        report.escalated.intersection(&report.direct).next().is_none(),
        "a direct-routed request can never also be an escalation"
    );

    let cheap = &report.coserve.lanes[CHEAP_LANE].metrics;
    let mut cheap_seen = HashSet::new();
    for c in &cheap.completions {
        assert!(trace_ids.contains(&c.id), "cheap lane saw foreign request {}", c.id);
        assert!(
            !report.direct.contains(&c.id),
            "direct-routed {} must never visit the cheap lane",
            c.id
        );
        assert!(cheap_seen.insert(c.id), "cheap lane double-recorded {}", c.id);
    }
    assert_eq!(
        cheap_seen.len(),
        trace_ids.len() - report.direct.len(),
        "cheap lane lost requests"
    );

    let cheap_completed: HashSet<u64> = cheap
        .completions
        .iter()
        .filter(|c| c.outcome == Outcome::Completed)
        .map(|c| c.id)
        .collect();

    let heavy = &report.coserve.lanes[HEAVY_LANE].metrics;
    let mut heavy_seen = BTreeSet::new();
    let mut direct_seen = BTreeSet::new();
    for c in &heavy.completions {
        if c.id & ESC_BIT == 0 {
            assert!(
                report.direct.contains(&c.id),
                "heavy lane saw an untagged, non-direct request {}",
                c.id
            );
            assert!(direct_seen.insert(c.id), "heavy lane double-recorded direct {}", c.id);
            continue;
        }
        let orig = c.id & !ESC_BIT;
        assert!(report.escalated.contains(&orig), "heavy served non-escalated {orig}");
        assert!(
            cheap_completed.contains(&orig),
            "escalated {orig} without a completed cheap serving"
        );
        assert!(heavy_seen.insert(orig), "heavy lane double-recorded {orig}");
    }
    assert_eq!(
        heavy_seen,
        report.escalated,
        "every escalation must be accounted on the heavy lane exactly once"
    );
    assert_eq!(
        direct_seen,
        report.direct,
        "every direct-routed request must be accounted on the heavy lane exactly once"
    );

    // Logical roll-up: one final verdict per trace request.
    let mut logical_seen = HashSet::new();
    for c in &report.logical.completions {
        assert!(trace_ids.contains(&c.id), "logical roll-up invented request {}", c.id);
        assert!(logical_seen.insert(c.id), "logical roll-up duplicated {}", c.id);
    }
    assert_eq!(logical_seen.len(), trace_ids.len());
    assert_eq!(report.logical.quality.len(), trace_ids.len(), "one verdict per request");
}

#[test]
fn cascade_conserves_requests_across_escalations_and_rearbitration() {
    let cluster = ClusterSpec::l20(4); // 32 shared GPUs
    let (cheap, heavy) = setups(&cluster);
    let trace = logical_trace(&heavy, DifficultyModel::Uniform, 3);
    assert!(trace.requests.len() > 300, "trace too thin: {}", trace.requests.len());

    let mut arbiter =
        ForcedSwap { inner: ClusterArbiter::new(cluster.gpus_per_node), at_ms: 60_000.0, fired: false };
    let report = run_cascade(
        &cheap,
        &heavy,
        &cluster,
        &mut arbiter,
        &trace,
        RouterMode::StaticThreshold(0.5),
        QualityModel::default(),
        &cfg(3),
    );

    assert!(report.coserve.arbitrations >= 1, "forced node move never applied");
    assert!(report.coserve.moved_gpus >= cluster.gpus_per_node);
    assert_eq!(report.coserve.vram_violations, 0, "VRAM ledger violated");
    // Uniform difficulty at τ=0.5 must escalate a substantial share.
    assert!(report.escalations() > 50, "only {} escalations", report.escalations());
    assert_conservation(&report, &trace);
    let nodes: usize = report.coserve.lanes.iter().map(|l| l.nodes_final).sum();
    assert_eq!(nodes, cluster.nodes);
}

#[test]
fn arrival_routing_conserves_and_partitions_the_stream() {
    // Predicted-difficulty routing: requests predicted hard at arrival skip
    // the cheap pass entirely. The escalation-conservation contract must
    // hold with the stream partitioned three ways — cheap-kept,
    // cheap-then-escalated, and direct-to-heavy — while the feedback
    // controller walks the arrival cut against observed escalation waste.
    let cluster = ClusterSpec::l20(4);
    let (cheap, heavy) = setups(&cluster);
    let trace = logical_trace(&heavy, DifficultyModel::Uniform, 9);
    let quality = QualityModel::default();
    let cut = 0.75;

    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    let report = run_cascade(
        &cheap,
        &heavy,
        &cluster,
        &mut arbiter,
        &trace,
        RouterMode::ArrivalRouted { predicted_cut: cut, threshold: 0.5 },
        quality,
        &cfg(9),
    );

    assert_conservation(&report, &trace);
    assert_eq!(report.coserve.vram_violations, 0);

    // The direct set is exactly the arrival rule under the *controlled*
    // cut, re-derived by replaying the recorded cut trace: each request is
    // judged against the cut in force at its arrival (the last adjustment
    // strictly before it — ticks at the same timestamp run after arrivals).
    assert!(!report.arrival_cut_trace.is_empty(), "cut trace must be recorded");
    let cut_at = |t: f64| {
        report
            .arrival_cut_trace
            .iter()
            .take_while(|(tc, _)| *tc < t)
            .last()
            .map(|&(_, c)| c)
            .unwrap_or(cut)
    };
    let expected: std::collections::BTreeSet<u64> = trace
        .requests
        .iter()
        .filter(|r| quality.predicted_difficulty(r.id, r.difficulty) > cut_at(r.arrival_ms))
        .map(|r| r.id)
        .collect();
    assert_eq!(report.direct, expected, "direct routing must match the arrival rule");
    // Uniform difficulty at τ=0.5 escalates ~a third of the cheap stream at
    // the initial 0.75 cut — above the 25% waste target — so the controller
    // must have walked the cut down from its day-one value.
    assert!(
        report.final_arrival_cut < cut,
        "controller never adapted the cut: {} vs initial {cut}",
        report.final_arrival_cut
    );
    // A real minority goes direct, and the cheap-routed majority still
    // produces escalations.
    assert!(report.direct_routed() > 20, "only {} direct-routed", report.direct_routed());
    assert!(
        report.direct_routed() * 2 < trace.requests.len(),
        "direct routing swallowed the stream"
    );
    assert!(report.escalations() > 20, "only {} escalations", report.escalations());
}

#[test]
fn adaptive_cascade_holds_quality_floor_under_drift() {
    let cluster = ClusterSpec::l20(4);
    let (cheap, heavy) = setups(&cluster);
    let drift = DifficultyModel::Drift { from: 0.2, to: 0.55 };
    let trace = logical_trace(&heavy, drift, 11);
    let quality = QualityModel { adequacy_cut: 0.55, conf_noise: 0.10 };
    let floor = 0.92;
    let tau0 = calibrate_threshold(&quality, &drift, 0.0, floor, 11);

    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    arbiter.cooldown_ms = 30_000.0;
    arbiter.trigger_streak = 1;
    let report = run_cascade(
        &cheap,
        &heavy,
        &cluster,
        &mut arbiter,
        &trace,
        RouterMode::Adaptive {
            initial_threshold: tau0,
            controller: ThresholdController::new(floor),
        },
        quality,
        &cfg(11),
    );

    assert_conservation(&report, &trace);
    assert_eq!(report.coserve.vram_violations, 0);
    // The feedback loop must hold the floor (small slack for the bootstrap
    // transient before the evidence window fills).
    let q = report.quality_attainment();
    assert!(q >= floor - 0.05, "quality {q} fell far below floor {floor}");
    // Under rising difficulty the controller must have raised the threshold.
    assert!(
        report.final_threshold > tau0,
        "threshold never adapted: {} vs initial {tau0}",
        report.final_threshold
    );
    // And the threshold trace is monitor-tick dense.
    assert!(report.threshold_trace.len() > 50);
    // Escalations happen but the majority of traffic stays cheap overall.
    let frac = report.escalation_fraction();
    assert!(frac > 0.05 && frac < 0.75, "escalation fraction {frac}");
}

#[test]
fn cascade_conserves_under_preemptive_resize() {
    // The cascade runs over the same lane machinery in either resize
    // scheme: under ResizePolicy::Preempt a forced node move cuts in-flight
    // work at stage/step boundaries, and the escalation-conservation
    // contract must still hold exactly.
    let cluster = ClusterSpec::l20(4);
    let (cheap, heavy) = setups(&cluster);
    let trace = logical_trace(&heavy, DifficultyModel::Uniform, 3);

    let mut arbiter = ForcedSwap {
        inner: ClusterArbiter::new(cluster.gpus_per_node),
        at_ms: 60_000.0,
        fired: false,
    };
    let report = run_cascade(
        &cheap,
        &heavy,
        &cluster,
        &mut arbiter,
        &trace,
        RouterMode::StaticThreshold(0.5),
        QualityModel::default(),
        &CoServeConfig { resize: ResizePolicy::Preempt, ..cfg(3) },
    );

    assert!(report.coserve.arbitrations >= 1, "forced node move never applied");
    assert_eq!(report.coserve.vram_violations, 0, "VRAM ledger violated");
    assert_eq!(
        report.coserve.migration.blackout_ms.len(),
        report.coserve.arbitrations
    );
    assert_conservation(&report, &trace);
    let nodes: usize = report.coserve.lanes.iter().map(|l| l.nodes_final).sum();
    assert_eq!(nodes, cluster.nodes);
}

#[test]
fn always_heavy_baseline_is_full_quality_no_escalation() {
    let cluster = ClusterSpec::l20(4);
    let (_, heavy) = setups(&cluster);
    let trace = logical_trace(&heavy, DifficultyModel::Uniform, 7);
    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    let report = run_cascade(
        &heavy,
        &heavy,
        &cluster,
        &mut arbiter,
        &trace,
        RouterMode::AlwaysHeavy,
        QualityModel::default(),
        &cfg(7),
    );
    assert_eq!(report.escalations(), 0);
    assert_eq!(report.coserve.lanes.len(), 1, "always-heavy runs one lane");
    assert_eq!(report.logical.completions.len(), trace.requests.len());
    // Quality == completion rate: every produced output is full-strength.
    let completed = report
        .logical
        .completions
        .iter()
        .filter(|c| c.outcome == Outcome::Completed)
        .count();
    let expect = completed as f64 / trace.requests.len() as f64;
    assert!((report.quality_attainment() - expect).abs() < 1e-9);
    assert!(report.quality_attainment() > 0.9, "moderate load must mostly complete");
}

#[test]
fn cascade_is_deterministic_per_seed() {
    let cluster = ClusterSpec::l20(4);
    let (cheap, heavy) = setups(&cluster);
    let trace = logical_trace(&heavy, DifficultyModel::Uniform, 5);
    let run = || {
        let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
        run_cascade(
            &cheap,
            &heavy,
            &cluster,
            &mut arbiter,
            &trace,
            RouterMode::StaticThreshold(0.45),
            QualityModel::default(),
            &cfg(5),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.escalated, b.escalated);
    assert_eq!(a.logical.completions.len(), b.logical.completions.len());
    assert_eq!(a.logical.slo_attainment(), b.logical.slo_attainment());
    assert_eq!(a.quality_attainment(), b.quality_attainment());
    assert_eq!(a.coserve.arbitrations, b.coserve.arbitrations);
}
