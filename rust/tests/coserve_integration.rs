//! Co-serving integration: two pipelines with shifting load share one
//! cluster end-to-end. Exercises the full arbitration path — trigger,
//! drain, node handoff — and pins the conservation invariants: every
//! request of the mixed trace is accounted for exactly once, none is
//! double-executed, and the VRAM ledger holds throughout.

use std::collections::HashSet;

use tridentserve::baselines::StaticPartition;
use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    run_coserve, CoServeConfig, CoServeReport, ClusterArbiter, PipelineSetup, ResizePolicy,
};
use tridentserve::request::Outcome;
use tridentserve::workload::{mixed, DifficultyModel, LoadShape, MixedSpec, MixedTrace, WorkloadKind};

const DURATION_MS: f64 = 240_000.0;

fn scenario(cluster: &ClusterSpec, seed: u64) -> (Vec<PipelineSetup>, MixedTrace) {
    let sd3 = PipelineSetup::new("sd3", cluster);
    let flux = PipelineSetup::new("flux", cluster);
    let trace = {
        let specs = [
            // Sd3-heavy first half, then collapse.
            MixedSpec {
                pipeline: &sd3.pipeline,
                profile: &sd3.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.12,
                load: LoadShape::Step { at: 0.5, before: 1.6, after: 0.3 },
                difficulty: DifficultyModel::Uniform,
            },
            // Flux quiet first half, then 5.3x surge — this overloads any
            // average-sized static share and must force a re-arbitration.
            MixedSpec {
                pipeline: &flux.pipeline,
                profile: &flux.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.15,
                load: LoadShape::Step { at: 0.5, before: 0.3, after: 1.6 },
                difficulty: DifficultyModel::Uniform,
            },
        ];
        mixed(&specs, DURATION_MS, seed)
    };
    (vec![sd3, flux], trace)
}

fn reactive_cfg(seed: u64) -> CoServeConfig {
    CoServeConfig {
        seed,
        monitor_ms: 2_000.0,
        backlog_trigger_per_gpu: 0.1,
        ..Default::default()
    }
}

fn preempt_cfg(seed: u64) -> CoServeConfig {
    CoServeConfig { resize: ResizePolicy::Preempt, ..reactive_cfg(seed) }
}

/// Every trace request appears in its lane's completions exactly once, with
/// the correct pipeline attribution; completed requests are unique (no
/// double execution).
fn assert_conservation(report: &CoServeReport, trace: &MixedTrace) {
    assert_eq!(report.lanes.len(), trace.n_pipelines);
    for (p, lane) in report.lanes.iter().enumerate() {
        let expected: HashSet<u64> = trace.of_pipeline(p).map(|r| r.id).collect();
        let mut seen = HashSet::new();
        for c in &lane.metrics.completions {
            assert!(
                expected.contains(&c.id),
                "lane {p} recorded request {} it never received",
                c.id
            );
            assert!(seen.insert(c.id), "lane {p} double-recorded request {}", c.id);
        }
        assert_eq!(
            seen.len(),
            expected.len(),
            "lane {p} lost {} request(s)",
            expected.len() - seen.len()
        );
        // Completed implies served exactly once with a real finish time.
        for c in &lane.metrics.completions {
            if c.outcome == Outcome::Completed {
                assert!(c.finish_ms.is_finite());
                assert!(c.finish_ms >= c.arrival_ms);
            }
        }
    }
    let total: usize = report.lanes.iter().map(|l| l.metrics.completions.len()).sum();
    assert_eq!(total, trace.requests.len());
}

#[test]
fn arbitration_end_to_end_conserves_requests() {
    let cluster = ClusterSpec::l20(6); // 48 shared GPUs
    let (setups, trace) = scenario(&cluster, 5);
    assert!(trace.of_pipeline(0).count() > 100, "sd3 side of the trace is empty");
    assert!(trace.of_pipeline(1).count() > 20, "flux side of the trace is empty");

    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    arbiter.cooldown_ms = 15_000.0;
    arbiter.trigger_streak = 1;
    let report = run_coserve(&setups, &cluster, &mut arbiter, &trace, &reactive_cfg(5));

    // The flux surge must have forced at least one applied re-arbitration
    // (drain completed, nodes changed hands).
    assert!(
        report.arbitrations >= 1,
        "no re-arbitration despite a 5.3x load shift"
    );
    assert!(report.moved_gpus >= cluster.gpus_per_node, "nodes must actually move");
    assert_eq!(report.vram_violations, 0, "VRAM ledger invariants violated");
    assert_conservation(&report, &trace);

    // Allocation still covers the whole cluster after all the churn.
    let nodes: usize = report.lanes.iter().map(|l| l.nodes_final).sum();
    assert_eq!(nodes, cluster.nodes);

    // The system actually served under churn: a healthy majority of
    // requests completed (not lost to drain pauses).
    let completed: usize = report
        .lanes
        .iter()
        .map(|l| {
            l.metrics
                .completions
                .iter()
                .filter(|c| c.outcome == Outcome::Completed)
                .count()
        })
        .sum();
    assert!(
        completed * 2 > trace.requests.len(),
        "only {completed}/{} requests completed",
        trace.requests.len()
    );
}

#[test]
fn preemptive_resize_conserves_requests_end_to_end() {
    // The same churn scenario as the drain test, under ResizePolicy::Preempt:
    // in-flight work is cut at stage/step boundaries, checkpointed, and
    // adopted by the rebuilt engines — the conservation contract must hold
    // exactly (no loss, no double execution) and the VRAM ledger must be
    // clean at every preemption point.
    let cluster = ClusterSpec::l20(6);
    let (setups, trace) = scenario(&cluster, 5);

    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    arbiter.cooldown_ms = 15_000.0;
    arbiter.trigger_streak = 1;
    let report = run_coserve(&setups, &cluster, &mut arbiter, &trace, &preempt_cfg(5));

    assert!(
        report.arbitrations >= 1,
        "no re-arbitration despite a 5.3x load shift"
    );
    assert!(report.moved_gpus >= cluster.gpus_per_node, "nodes must actually move");
    assert_eq!(report.vram_violations, 0, "VRAM ledger violated at a preemption point");
    assert_conservation(&report, &trace);
    let nodes: usize = report.lanes.iter().map(|l| l.nodes_final).sum();
    assert_eq!(nodes, cluster.nodes);

    // Migration bookkeeping is internally consistent.
    let m = &report.migration;
    assert_eq!(
        m.blackout_ms.len(),
        report.arbitrations,
        "one blackout record per applied re-arbitration"
    );
    assert!(m.blackout_ms.iter().all(|&b| b >= 0.0));
    assert!(m.checkpointed_gb >= 0.0);
    assert!(
        m.migrated_gb <= m.checkpointed_gb + 1e-9,
        "restores cannot exceed what was checkpointed"
    );
    if m.resumed > 0 {
        assert!(
            m.checkpointed_gb > 0.0,
            "resumed work implies a saved inter-stage tensor or latent"
        );
    }

    // Preemption must not break serving: a healthy majority completes.
    let completed: usize = report
        .lanes
        .iter()
        .map(|l| {
            l.metrics
                .completions
                .iter()
                .filter(|c| c.outcome == Outcome::Completed)
                .count()
        })
        .sum();
    assert!(
        completed * 2 > trace.requests.len(),
        "only {completed}/{} requests completed under preemptive churn",
        trace.requests.len()
    );
}

#[test]
fn preemptive_resize_is_deterministic_per_seed() {
    let cluster = ClusterSpec::l20(6);
    let (setups, trace) = scenario(&cluster, 7);
    let run = || {
        let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
        arbiter.cooldown_ms = 15_000.0;
        arbiter.trigger_streak = 1;
        run_coserve(&setups, &cluster, &mut arbiter, &trace, &preempt_cfg(7))
    };
    let a = run();
    let b = run();
    assert_eq!(a.arbitrations, b.arbitrations);
    assert_eq!(a.moved_gpus, b.moved_gpus);
    assert_eq!(a.migration.blackout_ms, b.migration.blackout_ms);
    assert_eq!(a.migration.preemptions, b.migration.preemptions);
    assert_eq!(a.migration.resumed, b.migration.resumed);
    assert_eq!(a.migration.restarted, b.migration.restarted);
    assert!((a.migration.checkpointed_gb - b.migration.checkpointed_gb).abs() < 1e-9);
    for (la, lb) in a.lanes.iter().zip(&b.lanes) {
        assert_eq!(la.metrics.completions.len(), lb.metrics.completions.len());
        assert_eq!(la.metrics.slo_attainment(), lb.metrics.slo_attainment());
    }
}

#[test]
fn drain_mode_records_blackouts_but_never_checkpoints() {
    // Drain is unchanged behaviorally but now reports its blackouts, so the
    // two schemes are directly comparable; it must never produce migration
    // work.
    let cluster = ClusterSpec::l20(6);
    let (setups, trace) = scenario(&cluster, 5);
    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    arbiter.cooldown_ms = 15_000.0;
    arbiter.trigger_streak = 1;
    let report = run_coserve(&setups, &cluster, &mut arbiter, &trace, &reactive_cfg(5));
    assert_eq!(report.resize, ResizePolicy::Drain);
    assert_eq!(report.migration.blackout_ms.len(), report.arbitrations);
    assert_eq!(report.migration.preemptions, 0);
    assert_eq!(report.migration.resumed, 0);
    assert_eq!(report.migration.restarted, 0);
    assert_eq!(report.migration.checkpointed_gb, 0.0);
    // The counters surface without private accessors: JSON + Display.
    let j = report.to_json().to_string();
    let parsed = tridentserve::util::json::Json::parse(&j).unwrap();
    assert_eq!(
        parsed.get("resize").unwrap().as_str(),
        Some("drain"),
        "resize scheme serialised"
    );
    assert!(parsed.get("migration").is_some());
    let shown = format!("{report}");
    assert!(shown.contains("migration:"), "{shown}");
    assert!(shown.contains("drain"), "{shown}");
}

#[test]
fn static_partition_conserves_and_never_moves() {
    let cluster = ClusterSpec::l20(6);
    let (setups, trace) = scenario(&cluster, 5);
    let mut fixed = StaticPartition::new();
    let report = run_coserve(&setups, &cluster, &mut fixed, &trace, &reactive_cfg(5));
    assert_eq!(report.arbitrations, 0);
    assert_eq!(report.moved_gpus, 0);
    assert_eq!(report.vram_violations, 0);
    assert_conservation(&report, &trace);
    let nodes: usize = report.lanes.iter().map(|l| l.nodes_final).sum();
    assert_eq!(nodes, cluster.nodes);
}

#[test]
fn per_pipeline_metrics_are_separated() {
    let cluster = ClusterSpec::l20(6);
    let (setups, trace) = scenario(&cluster, 11);
    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    arbiter.cooldown_ms = 15_000.0;
    arbiter.trigger_streak = 1;
    let report = run_coserve(&setups, &cluster, &mut arbiter, &trace, &reactive_cfg(11));
    assert_eq!(report.lanes[0].pipeline, "sd3");
    assert_eq!(report.lanes[1].pipeline, "flux");
    // Shape indices stay inside each lane's own shape table (no
    // cross-pipeline leakage of requests).
    for (p, lane) in report.lanes.iter().enumerate() {
        let n_shapes = setups[p].pipeline.shapes.len();
        for c in &lane.metrics.completions {
            assert!(c.shape_idx < n_shapes, "lane {p} saw a foreign shape");
        }
    }
    // Aggregate SLO is a weighted combination of the per-lane rates.
    let agg = report.aggregate_slo();
    let (lo, hi) = report
        .lanes
        .iter()
        .map(|l| l.metrics.slo_attainment())
        .fold((1.0f64, 0.0f64), |(lo, hi), s| (lo.min(s), hi.max(s)));
    assert!(agg >= lo - 1e-9 && agg <= hi + 1e-9, "agg {agg} outside [{lo}, {hi}]");
}
