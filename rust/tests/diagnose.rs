//! Diagnosis integration (ISSUE 8 acceptance): the SLO burn-rate alerting
//! + root-cause attribution stack is pinned on four contracts, end-to-end
//! over real runs —
//!
//! * **Attribution** — seeded scenarios diagnose their planted root cause:
//!   an overloaded co-serve run attributes to queue growth, a node-churn
//!   run to fault blackout, and an escalation-storm cascade to cascade
//!   pressure (the escalated spans' carve-out);
//! * **Determinism** — the same seed yields a byte-identical diagnosis
//!   JSONL (the report is a pure function of the attainment series, the
//!   trace, and the policy, all of which are seed-deterministic);
//! * **Zero perturbation** — diagnosis runs post-hoc over exported
//!   artifacts, so a run that is diagnosed traces byte-identically to one
//!   that is not;
//! * **Replay fidelity** — parsing the exported JSONL trace + metrics CSV
//!   back (the `tridentserve diagnose` CLI path) reproduces the live
//!   registry-side diagnosis byte-for-byte.

use std::cell::RefCell;
use std::rc::Rc;

use tridentserve::cascade::{
    calibrate_threshold, run_cascade_observed, QualityModel, RouterMode, ThresholdController,
};
use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    run_coserve_faulty_observed, run_coserve_observed, ClusterArbiter, CoServeConfig,
    CoServeReport, FaultPlan, PipelineSetup, RecoveryPolicy,
};
use tridentserve::diagnose::{
    diagnose, diagnose_series, parse_jsonl_trace, parse_metrics_csv, Cause, DiagnosisReport,
    SloPolicy,
};
use tridentserve::faults::ChurnGen;
use tridentserve::obs::export::to_jsonl_with_dropped;
use tridentserve::obs::{RingSink, TraceConfig, TraceEvent, Tracer};
use tridentserve::telemetry::export::to_csv;
use tridentserve::telemetry::{metric, Registry, Telemetry};
use tridentserve::workload::{
    mixed, DifficultyModel, LoadShape, MixedSpec, MixedTrace, Trace, TraceGen, WorkloadKind,
};

const DURATION_MS: f64 = 120_000.0;

fn ring() -> (Tracer, Rc<RefCell<RingSink>>) {
    let (tracer, sink) = Tracer::ring(&TraceConfig::full());
    (tracer, sink.expect("full config always has a sink"))
}

fn arbiter(cluster: &ClusterSpec) -> ClusterArbiter {
    let mut a = ClusterArbiter::new(cluster.gpus_per_node);
    a.cooldown_ms = 20_000.0;
    a.trigger_streak = 1;
    a
}

/// Flat co-serve load at `rate_scale` on both pipelines: no load shift, so
/// the planted stressor (overload level, or churn) is the only pressure.
fn flat_scenario(
    cluster: &ClusterSpec,
    seed: u64,
    rate_scale: f64,
) -> (Vec<PipelineSetup>, MixedTrace) {
    let sd3 = PipelineSetup::new("sd3", cluster);
    let flux = PipelineSetup::new("flux", cluster);
    let trace = {
        let specs = [
            MixedSpec {
                pipeline: &sd3.pipeline,
                profile: &sd3.profile,
                kind: WorkloadKind::Medium,
                rate_scale,
                load: LoadShape::Flat,
                difficulty: DifficultyModel::Uniform,
            },
            MixedSpec {
                pipeline: &flux.pipeline,
                profile: &flux.profile,
                kind: WorkloadKind::Medium,
                rate_scale,
                load: LoadShape::Flat,
                difficulty: DifficultyModel::Uniform,
            },
        ];
        mixed(&specs, DURATION_MS, seed)
    };
    (vec![sd3, flux], trace)
}

struct Observed {
    report: CoServeReport,
    events: Vec<TraceEvent>,
    dropped: u64,
    reg: Rc<RefCell<Registry>>,
}

/// Sustained overload: flat 0.6x on a 4-node cluster (~2x the load the
/// telemetry suite's step peak applies) — queues grow, attainment burns.
fn overload_run(seed: u64) -> Observed {
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = flat_scenario(&cluster, seed, 0.6);
    let cfg = CoServeConfig { seed, ..Default::default() };
    let (tracer, sink) = ring();
    let (tele, reg) = Telemetry::registry();
    let mut arb = arbiter(&cluster);
    let report = run_coserve_observed(&setups, &cluster, &mut arb, &trace, &cfg, &tracer, &tele);
    let events = sink.borrow().snapshot();
    let dropped = sink.borrow().dropped;
    Observed { report, events, dropped, reg }
}

/// Aggressive node churn under light load: the only thing hurting latency
/// is kills and their recovery blackout, not queueing.
fn churn_run(seed: u64) -> Observed {
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = flat_scenario(&cluster, seed, 0.12);
    let churn = ChurnGen {
        mtbf_ms: 30_000.0,
        mean_downtime_ms: 45_000.0,
        spot_fraction: 0.5,
        notice_ms: 15_000.0,
        min_alive: 3,
        ..ChurnGen::default()
    }
    .generate(cluster.nodes, DURATION_MS, seed);
    assert!(!churn.events.is_empty(), "churn trace empty — nothing exercised");
    let plan = FaultPlan::new(churn, RecoveryPolicy::Reactive);
    let cfg = CoServeConfig { seed, monitor_ms: 2_500.0, ..Default::default() };
    let (tracer, sink) = ring();
    let (tele, reg) = Telemetry::registry();
    let mut arb = arbiter(&cluster);
    let report = run_coserve_faulty_observed(
        &setups, &cluster, &mut arb, &trace, &cfg, &plan, &tracer, &tele,
    );
    assert!(report.faults.node_losses > 0, "no capacity loss ever applied");
    let events = sink.borrow().snapshot();
    let dropped = sink.borrow().dropped;
    Observed { report, events, dropped, reg }
}

fn dominant_causes(rep: &DiagnosisReport) -> Vec<Cause> {
    rep.diagnoses.iter().filter_map(|d| d.dominant().map(|c| c.cause)).collect()
}

#[test]
fn overload_diagnoses_queue_growth_and_is_byte_deterministic() {
    let policy = SloPolicy::default();
    let o = overload_run(5);
    let rep = diagnose(&o.reg.borrow(), &o.events, o.dropped, &policy);
    assert!(
        !rep.diagnoses.is_empty(),
        "a 2x-overloaded run must fire SLO burn-rate alerts:\n{rep}"
    );
    // Every alert's top-ranked cause is queue growth: there are no faults,
    // no cascade, and resize blackouts are seconds against queue-minutes.
    let doms = dominant_causes(&rep);
    assert!(!doms.is_empty(), "alerts fired but no trace evidence attributed:\n{rep}");
    assert!(
        doms.iter().all(|&c| c == Cause::QueueGrowth),
        "overload must attribute to queue growth, got {doms:?}:\n{rep}"
    );

    // Same seed → byte-identical diagnosis JSONL, end to end.
    let o2 = overload_run(5);
    let rep2 = diagnose(&o2.reg.borrow(), &o2.events, o2.dropped, &policy);
    assert_eq!(rep.to_jsonl(), rep2.to_jsonl(), "same seed must diagnose byte-identically");
}

#[test]
fn churn_diagnoses_fault_blackout() {
    let policy = SloPolicy::default();
    let o = churn_run(7);
    let rep = diagnose(&o.reg.borrow(), &o.events, o.dropped, &policy);
    assert!(
        !rep.diagnoses.is_empty(),
        "a churn-battered run must fire SLO burn-rate alerts:\n{rep}"
    );
    // Lightly loaded: the only pressure is the kills and their recovery,
    // so at least one alert must rank fault blackout first.
    let doms = dominant_causes(&rep);
    assert!(
        doms.contains(&Cause::Blackout),
        "churn must attribute to fault blackout, got {doms:?}:\n{rep}"
    );

    let o2 = churn_run(7);
    let rep2 = diagnose(&o2.reg.borrow(), &o2.events, o2.dropped, &policy);
    assert_eq!(rep.to_jsonl(), rep2.to_jsonl(), "same seed must diagnose byte-identically");
}

#[test]
fn escalation_storm_diagnoses_cascade_pressure() {
    const CASCADE_DURATION_MS: f64 = 240_000.0;
    let cluster = ClusterSpec::l20(4);
    let cheap = PipelineSetup::new("sd3-turbo", &cluster);
    let heavy = PipelineSetup::new("sd3", &cluster);
    // Difficulty drifts far past the adequacy cut: by the second half most
    // requests fail the cheap pass and escalate, doubling their latency —
    // an escalation storm, not a queueing or fault problem.
    let drift = DifficultyModel::Drift { from: 0.3, to: 0.9 };
    let trace: Trace = {
        let mut tg = TraceGen::new(&heavy.pipeline, &heavy.profile);
        tg.rate_scale = 0.35;
        tg.difficulty = drift;
        tg.steady(WorkloadKind::Medium, CASCADE_DURATION_MS, 11)
    };
    let quality = QualityModel { adequacy_cut: 0.55, conf_noise: 0.10 };
    let floor = 0.92;
    let tau0 = calibrate_threshold(&quality, &drift, 0.0, floor, 11);
    let mode = RouterMode::Adaptive {
        initial_threshold: tau0,
        controller: ThresholdController::new(floor),
    };
    let cfg = CoServeConfig { seed: 11, monitor_ms: 2_000.0, ..Default::default() };

    let (tracer, sink) = ring();
    let (tele, reg) = Telemetry::registry();
    let mut arb = arbiter(&cluster);
    let report = run_cascade_observed(
        &cheap, &heavy, &cluster, &mut arb, &trace, mode, quality, &cfg, &tracer, &tele,
    );
    assert!(!report.escalated.is_empty(), "drift past the cut must force escalations");

    let events = sink.borrow().snapshot();
    let dropped = sink.borrow().dropped;
    let policy = SloPolicy::default();
    let rep = diagnose(&reg.borrow(), &events, dropped, &policy);
    assert!(
        !rep.diagnoses.is_empty(),
        "an escalation storm must fire SLO burn-rate alerts:\n{rep}"
    );
    let doms = dominant_causes(&rep);
    assert!(
        doms.contains(&Cause::EscalationStorm),
        "storm must attribute to escalation pressure, got {doms:?}:\n{rep}"
    );
}

#[test]
fn diagnosing_a_run_leaves_its_trace_byte_identical() {
    // Run A: traced only — no registry, no diagnosis.
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = flat_scenario(&cluster, 5, 0.6);
    let cfg = CoServeConfig { seed: 5, ..Default::default() };
    let (tracer, sink) = ring();
    let mut arb = arbiter(&cluster);
    let plain =
        run_coserve_observed(&setups, &cluster, &mut arb, &trace, &cfg, &tracer, &Telemetry::off());
    let jsonl_plain =
        to_jsonl_with_dropped(&sink.borrow().snapshot(), sink.borrow().dropped);

    // Run B: same seed, registry attached, diagnosis computed.
    let o = overload_run(5);
    let _ = diagnose(&o.reg.borrow(), &o.events, o.dropped, &SloPolicy::default());
    let jsonl_diagnosed = to_jsonl_with_dropped(&o.events, o.dropped);

    assert_eq!(
        jsonl_plain, jsonl_diagnosed,
        "diagnosis must be a pure post-hoc read: the trace cannot change"
    );
    let pc: usize = plain.lanes.iter().map(|l| l.metrics.completions.len()).sum();
    let oc: usize = o.report.lanes.iter().map(|l| l.metrics.completions.len()).sum();
    assert_eq!(pc, oc, "observing for diagnosis perturbed the run");
}

#[test]
fn replay_of_exported_artifacts_reproduces_the_live_diagnosis() {
    let policy = SloPolicy::default();
    let o = overload_run(13);
    let live = diagnose(&o.reg.borrow(), &o.events, o.dropped, &policy);
    assert!(!live.diagnoses.is_empty(), "need a firing run to make replay meaningful");

    // Export exactly what the examples (and CI) write to disk ...
    let jsonl = to_jsonl_with_dropped(&o.events, o.dropped);
    let csv = to_csv(&o.reg.borrow());
    // ... and feed it back through the `tridentserve diagnose` CLI path.
    let (events, dropped) = parse_jsonl_trace(&jsonl).expect("exported trace must parse");
    assert_eq!(dropped, o.dropped);
    let series = parse_metrics_csv(&csv, metric::SLO_ATTAINMENT).expect("exported CSV must parse");
    let replayed = diagnose_series(&series, &events, dropped, &policy);
    assert_eq!(
        live.to_jsonl(),
        replayed.to_jsonl(),
        "offline replay must reproduce the live diagnosis byte-for-byte"
    );
}
