//! Faults integration: co-serving under injected node churn, end-to-end.
//! Pins the three contracts the subsystem lives by:
//!
//! * **Determinism** — the same seed reproduces the identical churn trace
//!   AND the identical co-serving report (counters, blackouts, per-lane
//!   outcomes);
//! * **Conservation** — with failures active, issued == completed +
//!   re-queued-then-completed: every trace request is accounted exactly
//!   once per lane, none is lost to a dead node, none is duplicated by a
//!   recovery, across seeds and all three recovery policies;
//! * **Recovery semantics** — reclaim notices under proactive recovery
//!   need no detection and preserve completed work; reactive recovery
//!   detects by heartbeat staleness; every capacity loss produces exactly
//!   one per-failure blackout record.

use std::collections::HashSet;

use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    run_coserve_faulty, ClusterArbiter, CoServeConfig, CoServeReport, FaultPlan, PipelineSetup,
    RecoveryPolicy,
};
use tridentserve::faults::{ChurnEvent, ChurnGen, ChurnKind, ChurnTrace};
use tridentserve::request::Outcome;
use tridentserve::workload::{mixed, DifficultyModel, LoadShape, MixedSpec, MixedTrace, WorkloadKind};

const DURATION_MS: f64 = 180_000.0;

fn scenario(cluster: &ClusterSpec, seed: u64) -> (Vec<PipelineSetup>, MixedTrace) {
    let sd3 = PipelineSetup::new("sd3", cluster);
    let flux = PipelineSetup::new("flux", cluster);
    let trace = {
        let specs = [
            MixedSpec {
                pipeline: &sd3.pipeline,
                profile: &sd3.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.15,
                load: LoadShape::Flat,
                difficulty: DifficultyModel::Uniform,
            },
            MixedSpec {
                pipeline: &flux.pipeline,
                profile: &flux.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.3,
                load: LoadShape::Flat,
                difficulty: DifficultyModel::Uniform,
            },
        ];
        mixed(&specs, DURATION_MS, seed)
    };
    (vec![sd3, flux], trace)
}

fn cfg(seed: u64) -> CoServeConfig {
    CoServeConfig { seed, monitor_ms: 2_500.0, ..Default::default() }
}

fn gen_churn(cluster: &ClusterSpec, seed: u64) -> ChurnTrace {
    // Aggressive churn (expected ~6 failures per 3-minute trace) so no
    // seed can plausibly produce an event-free run.
    ChurnGen {
        mtbf_ms: 30_000.0,
        mean_downtime_ms: 45_000.0,
        spot_fraction: 0.5,
        notice_ms: 15_000.0,
        min_alive: 3,
        ..ChurnGen::default()
    }
    .generate(cluster.nodes, DURATION_MS, seed)
}

fn run(
    cluster: &ClusterSpec,
    setups: &[PipelineSetup],
    trace: &MixedTrace,
    seed: u64,
    churn: &ChurnTrace,
    recovery: RecoveryPolicy,
) -> CoServeReport {
    let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
    arbiter.cooldown_ms = 20_000.0;
    arbiter.trigger_streak = 1;
    let plan = FaultPlan::new(churn.clone(), recovery);
    run_coserve_faulty(setups, cluster, &mut arbiter, trace, &cfg(seed), &plan)
}

/// Issued == completed + re-queued-then-completed, with no duplication:
/// every trace request appears in its lane's completions exactly once (a
/// recovered request completes once, under its original id), and nothing
/// foreign appears.
fn assert_conservation(report: &CoServeReport, trace: &MixedTrace) {
    assert_eq!(report.lanes.len(), trace.n_pipelines);
    for (p, lane) in report.lanes.iter().enumerate() {
        let expected: HashSet<u64> = trace.of_pipeline(p).map(|r| r.id).collect();
        let mut seen = HashSet::new();
        for c in &lane.metrics.completions {
            assert!(
                expected.contains(&c.id),
                "lane {p} recorded request {} it never received",
                c.id
            );
            assert!(seen.insert(c.id), "lane {p} double-recorded request {}", c.id);
            if c.outcome == Outcome::Completed {
                assert!(c.finish_ms.is_finite());
                assert!(c.finish_ms >= c.arrival_ms);
            }
        }
        assert_eq!(
            seen.len(),
            expected.len(),
            "lane {p} lost {} request(s) to churn",
            expected.len() - seen.len()
        );
    }
    let total: usize = report.lanes.iter().map(|l| l.metrics.completions.len()).sum();
    assert_eq!(total, trace.requests.len());
}

#[test]
fn same_seed_identical_churn_and_report() {
    let cluster = ClusterSpec::l20(5);
    let (setups, trace) = scenario(&cluster, 7);
    let churn_a = gen_churn(&cluster, 7);
    let churn_b = gen_churn(&cluster, 7);
    assert_eq!(churn_a, churn_b, "same seed must produce the identical churn trace");
    assert!(!churn_a.events.is_empty(), "churn rates too low to exercise anything");
    assert_ne!(churn_a, gen_churn(&cluster, 8), "different seeds must differ");

    let a = run(&cluster, &setups, &trace, 7, &churn_a, RecoveryPolicy::Reactive);
    let b = run(&cluster, &setups, &trace, 7, &churn_b, RecoveryPolicy::Reactive);
    assert_eq!(a.arbitrations, b.arbitrations);
    assert_eq!(a.moved_gpus, b.moved_gpus);
    assert_eq!(a.faults.node_losses, b.faults.node_losses);
    assert_eq!(a.faults.detections, b.faults.detections);
    assert_eq!(a.faults.recovered, b.faults.recovered);
    assert_eq!(a.faults.restarted, b.faults.restarted);
    assert_eq!(a.faults.blackout_ms, b.faults.blackout_ms);
    assert_eq!(a.faults.lost_diffuse_ms, b.faults.lost_diffuse_ms);
    assert_eq!(a.migration.blackout_ms, b.migration.blackout_ms);
    for (la, lb) in a.lanes.iter().zip(&b.lanes) {
        assert_eq!(la.metrics.completions.len(), lb.metrics.completions.len());
        assert_eq!(la.metrics.slo_attainment(), lb.metrics.slo_attainment());
        assert_eq!(la.nodes_final, lb.nodes_final);
    }
}

#[test]
fn conservation_holds_across_seeds_and_policies() {
    let cluster = ClusterSpec::l20(5);
    for (seed, recovery) in [
        (3u64, RecoveryPolicy::Reactive),
        (5, RecoveryPolicy::Proactive),
        (9, RecoveryPolicy::ColdRestart),
        (11, RecoveryPolicy::Reactive),
    ] {
        let (setups, trace) = scenario(&cluster, seed);
        let churn = gen_churn(&cluster, seed);
        assert!(
            !churn.events.is_empty(),
            "seed {seed}: churn trace empty — nothing exercised"
        );
        let report = run(&cluster, &setups, &trace, seed, &churn, recovery);
        assert_eq!(
            report.vram_violations, 0,
            "seed {seed} {recovery:?}: VRAM ledger violated under churn"
        );
        assert_conservation(&report, &trace);
        assert!(
            report.faults.node_losses > 0,
            "seed {seed}: no capacity loss ever applied"
        );
        // Exactly one per-failure blackout record per capacity loss.
        assert_eq!(
            report.faults.blackout_ms.len(),
            report.faults.node_losses,
            "seed {seed} {recovery:?}: blackout accounting out of step"
        );
        // The system kept serving: churn must not collapse completion.
        let completed: usize = report
            .lanes
            .iter()
            .map(|l| {
                l.metrics
                    .completions
                    .iter()
                    .filter(|c| c.outcome == Outcome::Completed)
                    .count()
            })
            .sum();
        assert!(
            completed * 2 > trace.requests.len(),
            "seed {seed} {recovery:?}: only {completed}/{} completed",
            trace.requests.len()
        );
    }
}

#[test]
fn proactive_needs_no_detection_and_reactive_detects() {
    // One scripted reclaim with a generous notice, one hard failure later.
    let cluster = ClusterSpec::l20(5);
    let (setups, trace) = scenario(&cluster, 13);
    let churn = ChurnTrace::scripted(
        cluster.nodes,
        DURATION_MS,
        vec![
            ChurnEvent {
                t_ms: 40_000.0,
                node: 4,
                kind: ChurnKind::SpotReclaim { notice_ms: 20_000.0 },
            },
            ChurnEvent { t_ms: 90_000.0, node: 4, kind: ChurnKind::NodeUp },
            ChurnEvent { t_ms: 120_000.0, node: 3, kind: ChurnKind::NodeDown },
        ],
    );
    assert_eq!(churn.min_alive(), Some(4));

    let pro = run(&cluster, &setups, &trace, 13, &churn, RecoveryPolicy::Proactive);
    assert_eq!(pro.faults.reclaim_notices, 1);
    assert_eq!(pro.faults.node_losses, 2);
    assert_eq!(pro.faults.node_returns, 1);
    // The reclaim was handled from its notice — only the hard NodeDown
    // needed heartbeat detection.
    assert_eq!(pro.faults.detections, 1, "proactive must not detect announced reclaims");
    // The drained node was empty at its loss: one zero-blackout record.
    assert!(
        pro.faults.blackout_ms.iter().any(|&b| b == 0.0),
        "proactive reclaim should reach the loss with the node already drained: {:?}",
        pro.faults.blackout_ms
    );
    assert_eq!(pro.faults.re_executed_stages, 0);
    assert_conservation(&pro, &trace);

    let rea = run(&cluster, &setups, &trace, 13, &churn, RecoveryPolicy::Reactive);
    // Reactive ignores the notice: both losses are detected by staleness.
    assert_eq!(rea.faults.detections, 2, "reactive must detect every loss");
    assert_eq!(rea.faults.node_losses, 2);
    // Detection lag bounds the blackout from below: no reactive blackout
    // can beat the staleness threshold.
    let plan = FaultPlan::new(churn, RecoveryPolicy::Reactive);
    for &b in &rea.faults.blackout_ms {
        assert!(
            b >= plan.suspect_after_ms,
            "reactive blackout {b}ms under the detection threshold {}ms",
            plan.suspect_after_ms
        );
    }
    assert_conservation(&rea, &trace);
}

#[test]
fn node_returns_re_expand_the_pool() {
    // Lose a node, get it back, and end with every node allocated again.
    let cluster = ClusterSpec::l20(5);
    let (setups, trace) = scenario(&cluster, 17);
    let churn = ChurnTrace::scripted(
        cluster.nodes,
        DURATION_MS,
        vec![
            ChurnEvent { t_ms: 30_000.0, node: 2, kind: ChurnKind::NodeDown },
            ChurnEvent { t_ms: 80_000.0, node: 2, kind: ChurnKind::NodeUp },
        ],
    );
    let report = run(&cluster, &setups, &trace, 17, &churn, RecoveryPolicy::Reactive);
    assert_eq!(report.faults.node_losses, 1);
    assert_eq!(report.faults.node_returns, 1);
    assert!(report.arbitrations >= 2, "shrink and re-expansion must both apply");
    let nodes: usize = report.lanes.iter().map(|l| l.nodes_final).sum();
    assert_eq!(nodes, cluster.nodes, "the returned node must be re-allocated");
    assert_conservation(&report, &trace);
}

#[test]
fn back_to_back_losses_mid_recovery_stay_conserved() {
    // A second hard failure lands while the first is still being detected
    // and rebuilt: overlapping recoveries must absorb both losses without
    // losing or duplicating a single request, and the interleaving must
    // replay identically under the same seed.
    let cluster = ClusterSpec::l20(5);
    let (setups, trace) = scenario(&cluster, 19);
    let churn = ChurnTrace::scripted(
        cluster.nodes,
        DURATION_MS,
        vec![
            ChurnEvent { t_ms: 50_000.0, node: 1, kind: ChurnKind::NodeDown },
            // 2s later: inside node 1's staleness window (7.5s default), so
            // the second loss arrives before the first is even detected.
            ChurnEvent { t_ms: 52_000.0, node: 3, kind: ChurnKind::NodeDown },
            ChurnEvent { t_ms: 110_000.0, node: 1, kind: ChurnKind::NodeUp },
            ChurnEvent { t_ms: 120_000.0, node: 3, kind: ChurnKind::NodeUp },
        ],
    );
    assert_eq!(churn.min_alive(), Some(3));
    let plan = FaultPlan::new(churn.clone(), RecoveryPolicy::Reactive);
    assert!(plan.suspect_after_ms > 2_000.0, "the second loss must land mid-detection");

    let a = run(&cluster, &setups, &trace, 19, &churn, RecoveryPolicy::Reactive);
    let b = run(&cluster, &setups, &trace, 19, &churn, RecoveryPolicy::Reactive);
    assert_eq!(a.faults.node_losses, 2);
    assert_eq!(a.faults.node_returns, 2);
    assert_eq!(a.faults.detections, 2, "both hard losses need heartbeat detection");
    assert_eq!(a.faults.blackout_ms.len(), 2, "one blackout record per loss, even overlapped");
    assert_conservation(&a, &trace);
    // Same seed, same overlapping-recovery interleaving, bit for bit.
    assert_eq!(a.faults.blackout_ms, b.faults.blackout_ms);
    assert_eq!(a.faults.lost_diffuse_ms, b.faults.lost_diffuse_ms);
    assert_eq!(a.arbitrations, b.arbitrations);
    for (la, lb) in a.lanes.iter().zip(&b.lanes) {
        assert_eq!(la.metrics.completions.len(), lb.metrics.completions.len());
        assert_eq!(la.metrics.slo_attainment(), lb.metrics.slo_attainment());
        assert_eq!(la.nodes_final, lb.nodes_final);
    }
}

#[test]
fn whole_domain_loss_pins_the_min_alive_floor() {
    // Three of five nodes vanish at once — the pool drops to the two-lane
    // min-nodes floor — under the full hardened kit (standby spare,
    // periodic checkpoints, armed degrade ladder). Everything must stay
    // accounted: completed, shed, and deferred requests alike, with the
    // whole response replaying identically under the same seed.
    let cluster = ClusterSpec::l20(5);
    let (setups, trace) = scenario(&cluster, 23);
    let churn = ChurnTrace::scripted(
        cluster.nodes,
        DURATION_MS,
        vec![
            ChurnEvent { t_ms: 45_000.0, node: 2, kind: ChurnKind::DomainDown { width: 3 } },
            ChurnEvent { t_ms: 100_000.0, node: 2, kind: ChurnKind::NodeUp },
            ChurnEvent { t_ms: 105_000.0, node: 3, kind: ChurnKind::NodeUp },
            ChurnEvent { t_ms: 110_000.0, node: 4, kind: ChurnKind::NodeUp },
        ],
    );
    assert_eq!(churn.min_alive(), Some(2), "the domain loss pins the two-lane floor");

    let run_hardened = |seed: u64| {
        let mut arbiter = ClusterArbiter::new(cluster.gpus_per_node);
        arbiter.cooldown_ms = 20_000.0;
        arbiter.trigger_streak = 1;
        arbiter.standby_nodes = 1;
        let plan = FaultPlan::hardened(churn.clone(), RecoveryPolicy::Reactive);
        run_coserve_faulty(&setups, &cluster, &mut arbiter, &trace, &cfg(seed), &plan)
    };
    let a = run_hardened(23);
    let b = run_hardened(23);
    assert_eq!(a.faults.node_losses, 3, "every domain member is a capacity loss");
    assert_eq!(a.faults.node_returns, 3);
    assert_eq!(a.faults.blackout_ms.len(), 3, "one blackout record per member");
    assert_conservation(&a, &trace);
    // Shed arrivals are accounted, not dropped: the fault ledger and the
    // per-lane completion records must tell the same story.
    let shed: usize = a
        .lanes
        .iter()
        .map(|l| l.metrics.completions.iter().filter(|c| c.outcome == Outcome::Shed).count())
        .sum();
    assert_eq!(shed, a.faults.shed, "lane shed records must match the fault ledger");
    // Hardened determinism: ladder steps, checkpoint banking, shed and
    // defer decisions all replay under the same seed.
    assert_eq!(a.faults.shed, b.faults.shed);
    assert_eq!(a.faults.deferred, b.faults.deferred);
    assert_eq!(a.faults.degrade_transitions, b.faults.degrade_transitions);
    assert_eq!(a.faults.periodic_ckpts, b.faults.periodic_ckpts);
    assert_eq!(a.faults.blackout_ms, b.faults.blackout_ms);
    assert_eq!(a.faults.lost_diffuse_ms, b.faults.lost_diffuse_ms);
    for (la, lb) in a.lanes.iter().zip(&b.lanes) {
        assert_eq!(la.metrics.completions.len(), lb.metrics.completions.len());
        assert_eq!(la.metrics.slo_attainment(), lb.metrics.slo_attainment());
    }
}
