//! Observability integration (ISSUE 6 acceptance): the trace subsystem is
//! pinned on three contracts, end-to-end over real runs —
//!
//! * **Determinism** — the same seed yields a byte-identical JSONL trace
//!   (events carry only simulation-time quantities), and attaching a
//!   tracer does not perturb the run it observes;
//! * **Conservation** — every served request's breakdown components
//!   (queue / transfer / per-stage exec / handoff / blackout) sum to its
//!   end-to-end latency within float tolerance, across the single-pipeline
//!   sim, co-serving, preemptive migration and fault-recovery paths;
//! * **Exportability** — the Chrome trace-event JSON built from a real
//!   run's events satisfies the schema Perfetto's importer enforces.

use std::cell::RefCell;
use std::rc::Rc;

use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    run_coserve_faulty_traced, run_coserve_traced, ClusterArbiter, CoServeConfig, CoServeReport,
    FaultPlan, PipelineSetup, RecoveryPolicy, ResizePolicy,
};
use tridentserve::faults::ChurnGen;
use tridentserve::harness::Setup;
use tridentserve::obs::export::{to_chrome_trace, to_jsonl};
use tridentserve::obs::report::BreakdownReport;
use tridentserve::obs::{EventBody, RingSink, TraceConfig, TraceEvent, Tracer};
use tridentserve::request::Outcome;
use tridentserve::util::json::Json;
use tridentserve::workload::{
    mixed, DifficultyModel, LoadShape, MixedSpec, MixedTrace, WorkloadKind,
};

const DURATION_MS: f64 = 120_000.0;

/// Conservation tolerance: residuals are pure float-associativity noise
/// (sub-nanosecond on millisecond-scale sums).
const RESIDUAL_TOL_MS: f64 = 1e-6;

fn ring() -> (Tracer, Rc<RefCell<RingSink>>) {
    let (tracer, sink) = Tracer::ring(&TraceConfig::full());
    (tracer, sink.expect("full config always has a sink"))
}

fn scenario(cluster: &ClusterSpec, seed: u64) -> (Vec<PipelineSetup>, MixedTrace) {
    let sd3 = PipelineSetup::new("sd3", cluster);
    let flux = PipelineSetup::new("flux", cluster);
    let trace = {
        let specs = [
            MixedSpec {
                pipeline: &sd3.pipeline,
                profile: &sd3.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.2,
                load: LoadShape::Step { at: 0.5, before: 1.4, after: 0.4 },
                difficulty: DifficultyModel::Uniform,
            },
            MixedSpec {
                pipeline: &flux.pipeline,
                profile: &flux.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.2,
                load: LoadShape::Step { at: 0.5, before: 0.4, after: 1.4 },
                difficulty: DifficultyModel::Uniform,
            },
        ];
        mixed(&specs, DURATION_MS, seed)
    };
    (vec![sd3, flux], trace)
}

fn arbiter(cluster: &ClusterSpec) -> ClusterArbiter {
    let mut a = ClusterArbiter::new(cluster.gpus_per_node);
    a.cooldown_ms = 20_000.0;
    a.trigger_streak = 1;
    a
}

fn completed(report: &CoServeReport) -> usize {
    report
        .lanes
        .iter()
        .map(|l| l.metrics.completions.iter().filter(|c| c.outcome == Outcome::Completed).count())
        .sum()
}

/// The trace's Done events must match the metrics' Completed outcomes
/// one-for-one, and every reconstructed span must conserve latency.
fn assert_conserves(events: &[TraceEvent], n_completed: usize, label: &str) {
    let report = BreakdownReport::from_events(events);
    assert!(!report.requests.is_empty(), "{label}: no served request reconstructed");
    assert_eq!(
        report.requests.len(),
        n_completed,
        "{label}: trace spans out of step with metrics completions"
    );
    assert!(
        report.max_residual_ms() < RESIDUAL_TOL_MS,
        "{label}: breakdown does not conserve latency (max residual {} ms)",
        report.max_residual_ms()
    );
}

/// The schema requirements Perfetto's importer enforces, checked on real
/// events (the unit test in `obs::export` covers hand-built ones).
fn assert_chrome_valid(events: &[TraceEvent], label: &str) {
    let text = to_chrome_trace(events).to_string();
    let v = Json::parse(&text).unwrap_or_else(|e| panic!("{label}: invalid JSON: {e:?}"));
    let evs = v.get("traceEvents").and_then(|j| j.as_arr()).expect("traceEvents array");
    assert!(!evs.is_empty(), "{label}: empty chrome trace");
    for e in evs {
        for key in ["name", "ph"] {
            assert!(e.get(key).and_then(|j| j.as_str()).is_some(), "{label}: missing {key}");
        }
        for key in ["pid", "tid", "ts"] {
            assert!(e.get(key).and_then(|j| j.as_f64()).is_some(), "{label}: missing {key}");
        }
        let ph = e.get("ph").unwrap().as_str().unwrap();
        assert!(matches!(ph, "X" | "i" | "M"), "{label}: unexpected phase {ph}");
        if ph == "X" {
            let dur = e.get("dur").and_then(|j| j.as_f64()).expect("X slice needs dur");
            assert!(dur >= 0.0, "{label}: negative slice duration");
        }
    }
    assert!(
        evs.iter().any(|e| e.get("ph").and_then(|j| j.as_str()) == Some("X")),
        "{label}: a real run must produce at least one stage slice"
    );
}

fn has_kind(events: &[TraceEvent], f: impl Fn(&EventBody) -> bool) -> bool {
    events.iter().any(|e| f(&e.body))
}

#[test]
fn sim_trace_is_deterministic_conserves_and_does_not_perturb() {
    let setup = Setup::new("sd3", 64);
    let (t1, s1) = ring();
    let m1 = setup.run_traced("trident", WorkloadKind::Medium, 60_000.0, 11, &t1);
    let (t2, s2) = ring();
    let m2 = setup.run_traced("trident", WorkloadKind::Medium, 60_000.0, 11, &t2);

    let e1 = s1.borrow().snapshot();
    let e2 = s2.borrow().snapshot();
    assert!(!e1.is_empty());
    assert_eq!(s1.borrow().dropped, 0, "full() capacity must hold a short run");
    let (j1, j2) = (to_jsonl(&e1), to_jsonl(&e2));
    assert_eq!(j1, j2, "same seed must produce a byte-identical JSONL trace");

    // Observing the run must not change it.
    let m0 = setup.run("trident", WorkloadKind::Medium, 60_000.0, 11);
    for (m, label) in [(&m1, "first traced"), (&m2, "second traced")] {
        assert_eq!(m.summary().n, m0.summary().n, "{label} run diverged from untraced");
        assert_eq!(
            m.summary().slo_attainment,
            m0.summary().slo_attainment,
            "{label} run diverged from untraced"
        );
    }

    let n_completed =
        m1.completions.iter().filter(|c| c.outcome == Outcome::Completed).count();
    assert_conserves(&e1, n_completed, "sim");
    assert!(has_kind(&e1, |b| matches!(b, EventBody::Decision { .. })), "no solve decisions");
    assert!(has_kind(&e1, |b| matches!(b, EventBody::Dispatch { .. })), "no dispatches");
}

#[test]
fn coserve_preempt_trace_conserves_and_exports() {
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = scenario(&cluster, 3);
    let cfg = CoServeConfig { seed: 3, resize: ResizePolicy::Preempt, ..Default::default() };
    let (tracer, sink) = ring();
    let mut arb = arbiter(&cluster);
    let report = run_coserve_traced(&setups, &cluster, &mut arb, &trace, &cfg, &tracer);

    let events = sink.borrow().snapshot();
    assert_conserves(&events, completed(&report), "coserve-preempt");
    assert_chrome_valid(&events, "coserve-preempt");
    // The opposed load step must have exercised the arbiter, and the trace
    // must show it.
    assert!(report.arbitrations > 0, "load step never triggered the arbiter");
    assert!(has_kind(&events, |b| matches!(b, EventBody::Swap { .. })), "no swap events");
    assert!(
        has_kind(&events, |b| matches!(b, EventBody::Repartition { .. })),
        "no repartition events"
    );
}

#[test]
fn faults_trace_is_deterministic_and_conserves() {
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = scenario(&cluster, 7);
    let churn = ChurnGen {
        mtbf_ms: 30_000.0,
        mean_downtime_ms: 45_000.0,
        spot_fraction: 0.5,
        notice_ms: 15_000.0,
        min_alive: 3,
        ..ChurnGen::default()
    }
    .generate(cluster.nodes, DURATION_MS, 7);
    assert!(!churn.events.is_empty(), "churn trace empty — nothing exercised");
    let plan = FaultPlan::new(churn, RecoveryPolicy::Reactive);
    let cfg = CoServeConfig { seed: 7, monitor_ms: 2_500.0, ..Default::default() };

    let run = || {
        let (tracer, sink) = ring();
        let mut arb = arbiter(&cluster);
        let report =
            run_coserve_faulty_traced(&setups, &cluster, &mut arb, &trace, &cfg, &plan, &tracer);
        (report, sink.borrow().snapshot())
    };
    let (ra, ea) = run();
    let (rb, eb) = run();
    assert_eq!(to_jsonl(&ea), to_jsonl(&eb), "same seed must trace byte-identically");
    assert_eq!(completed(&ra), completed(&rb));

    assert_conserves(&ea, completed(&ra), "faults-reactive");
    assert!(ra.faults.node_losses > 0, "no capacity loss ever applied");
    assert!(has_kind(&ea, |b| matches!(b, EventBody::NodeLoss { .. })), "no node-loss events");
    assert!(
        has_kind(&ea, |b| matches!(b, EventBody::Recovery { policy } if *policy == "reactive")),
        "no recovery events"
    );
    assert!(
        has_kind(&ea, |b| matches!(b, EventBody::ChurnDetect { .. })),
        "reactive recovery must log heartbeat detections"
    );
}
