//! Integration: the AOT HLO artifacts executed from Rust/PJRT must
//! reproduce the Python/JAX reference numerics (fixed seed, deterministic
//! inputs). Golden values were produced by python/compile/model.py with
//! seed 0 and the exact input constructions below.

use std::path::PathBuf;

use tridentserve::config::Stage;
use tridentserve::runtime::PjrtRuntime;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

fn sin_noise(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i as f32) * 0.618).sin() * 0.7).collect()
}

#[test]
fn full_pipeline_matches_python_goldens() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::load(
        &artifacts_dir(),
        Some(&["encode_b1", "diffuse_r128", "decode_r128"]),
    )
    .unwrap();

    // encode(tokens = arange(16) % 512)
    let tokens: Vec<i32> = (0..16).collect();
    let (cond, _) = rt.run_encode("encode_b1", &tokens, &[1, 16]).unwrap();
    assert_eq!(cond.len(), 16 * 64);
    // LayerNorm output: zero mean / unit variance per token.
    for t in 0..16 {
        let row = &cond[t * 64..(t + 1) * 64];
        let mean: f32 = row.iter().sum::<f32>() / 64.0;
        let var: f32 = row.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / 64.0;
        assert!(mean.abs() < 1e-4, "token {t} mean {mean}");
        assert!((var - 1.0).abs() < 2e-2, "token {t} var {var}");
    }

    // diffuse(noise = 0.7*sin(0.618*i)) — golden from python (seed 0):
    // latent absmax = 3.46551, decode absmax = 0.99620, mean|img| = 0.39709.
    let noise = sin_noise(32 * 32 * 8);
    let dims = [1i64, 32, 32, 8];
    let (latent, _) = rt
        .run_f32("diffuse_r128", &[(&noise, &dims), (&cond, &[1, 16, 64])])
        .unwrap();
    let absmax = latent.iter().fold(0f32, |a, &x| a.max(x.abs()));
    assert!((absmax - 3.46551).abs() < 2e-3, "latent absmax {absmax}");

    let (img, _) = rt.run_f32("decode_r128", &[(&latent, &dims)]).unwrap();
    assert_eq!(img.len(), 128 * 128 * 3);
    let absmax = img.iter().fold(0f32, |a, &x| a.max(x.abs()));
    let meanabs = img.iter().map(|x| x.abs()).sum::<f32>() / img.len() as f32;
    assert!((absmax - 0.99620).abs() < 2e-3, "img absmax {absmax}");
    assert!((meanabs - 0.39709).abs() < 2e-3, "img mean|.| {meanabs}");
}

#[test]
fn weights_are_not_elided() {
    // Regression for the constant({...}) elision bug: with zeroed weights
    // the diffuse artifact degenerates to the identity map.
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::load(&artifacts_dir(), Some(&["encode_b1", "diffuse_r64"])).unwrap();
    let tokens: Vec<i32> = (0..16).collect();
    let (cond, _) = rt.run_encode("encode_b1", &tokens, &[1, 16]).unwrap();
    let noise = sin_noise(16 * 16 * 8);
    let dims = [1i64, 16, 16, 8];
    let (latent, _) = rt
        .run_f32("diffuse_r64", &[(&noise, &dims), (&cond, &[1, 16, 64])])
        .unwrap();
    let delta: f32 = latent
        .iter()
        .zip(&noise)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(delta > 0.1, "diffuse must transform its input (max delta {delta})");
}

#[test]
fn all_resolution_variants_execute() {
    if !have_artifacts() {
        return;
    }
    let rt = PjrtRuntime::load(&artifacts_dir(), Some(&["encode_b1", "diffuse", "decode"])).unwrap();
    let tokens: Vec<i32> = (0..16).collect();
    let (cond, _) = rt.run_encode("encode_b1", &tokens, &[1, 16]).unwrap();
    for res in [64u32, 128, 256] {
        let side = (res / 4) as usize;
        let dims = [1i64, side as i64, side as i64, 8];
        let noise = sin_noise(side * side * 8);
        let d = rt.stage_artifact(Stage::Diffuse, res).unwrap();
        let (latent, _) = rt.run_f32(&d, &[(&noise, &dims), (&cond, &[1, 16, 64])]).unwrap();
        let c = rt.stage_artifact(Stage::Decode, res).unwrap();
        let (img, _) = rt.run_f32(&c, &[(&latent, &dims)]).unwrap();
        assert_eq!(img.len(), (res * res * 3) as usize, "res {res}");
        assert!(img.iter().all(|x| x.is_finite()));
    }
}
