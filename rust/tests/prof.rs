//! Control-plane self-profiling integration pins (ISSUE 9 acceptance):
//!
//! * same-seed profiled runs export byte-identical folded stacks and JSON
//!   summaries (wall channel excluded — the deterministic contract);
//! * a `Prof::off()` run's obs trace and metrics are byte-identical to an
//!   uninstrumented run, and a *recording* run perturbs neither (the
//!   profiler observes the control plane, never steers it);
//! * the scale-sweep schema carries per-phase fitted `_exponent` metrics
//!   and `bench-check`'s comparator rejects a synthetic superlinear
//!   regression;
//! * scope nesting/reentrancy hold at integration depth.

use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    run_coserve_profiled, ClusterArbiter, CoServeConfig, PipelineSetup, ResizePolicy,
};
use tridentserve::harness::Setup;
use tridentserve::obs::{export::to_jsonl, TraceConfig, Tracer};
use tridentserve::prof::export::{phase_totals, to_folded, to_json, Channel};
use tridentserve::prof::{Phase, Prof};
use tridentserve::telemetry::Telemetry;
use tridentserve::util::bench::{compare_benches, fit_loglog_exponent, BenchRecorder};
use tridentserve::workload::{mixed, DifficultyModel, LoadShape, MixedSpec, WorkloadKind};

const SIM_MS: f64 = 20_000.0;

/// One single-pipeline run through the profiled entry; returns the
/// metrics JSON (the run's observable output, for perturbation pins).
fn profiled_run(seed: u64, prof: &Prof, tracer: &Tracer) -> String {
    let setup = Setup::new("flux", 16);
    let m = setup.run_scaled_profiled(
        "trident",
        WorkloadKind::Medium,
        SIM_MS,
        seed,
        1.0,
        tracer,
        &Telemetry::off(),
        prof,
    );
    m.to_json("prof-pin").to_string()
}

#[test]
fn same_seed_exports_are_byte_identical() {
    let mut exports: Vec<(String, String, String)> = Vec::new();
    for _ in 0..2 {
        let (prof, sink) = Prof::recording();
        let _ = profiled_run(7, &prof, &Tracer::off());
        let sink = sink.borrow();
        exports.push((
            to_folded(&sink, Channel::Count),
            to_folded(&sink, Channel::Logical),
            to_json(&sink, false),
        ));
    }
    let (a, b) = (&exports[0], &exports[1]);
    assert!(!a.0.is_empty(), "profiled run recorded no phases");
    assert_eq!(a.0, b.0, "count folded stacks must be byte-identical across same-seed runs");
    assert_eq!(a.1, b.1, "logical folded stacks must be byte-identical across same-seed runs");
    assert_eq!(a.2, b.2, "pinned JSON export must be byte-identical across same-seed runs");
    // The taxonomy is visible where expected: dispatch nests under tick,
    // the MCKP solve nests under dispatch.
    assert!(a.0.contains("tick;dispatch "), "{}", a.0);
    assert!(
        a.0.contains("tick;dispatch;mckp_solve ") || a.0.contains("tick;dispatch;mckp_seeded "),
        "{}",
        a.0
    );
    // The deterministic export must carry no wall-clock channel.
    assert!(!a.2.contains("wall"), "pinned JSON leaked wall time: {}", a.2);
}

#[test]
fn profiling_perturbs_neither_trace_nor_metrics() {
    // Uninstrumented baseline: the pre-prof entry point.
    let setup = Setup::new("flux", 16);
    let (tr0, sink0) = Tracer::ring(&TraceConfig::full());
    let m0 = setup.run_scaled_traced("trident", WorkloadKind::Medium, SIM_MS, 3, 1.0, &tr0);
    let base_trace = to_jsonl(&sink0.unwrap().borrow().snapshot());
    let base_metrics = m0.to_json("prof-pin").to_string();

    // Prof::off() through the profiled entry: same bytes.
    let (tr1, sink1) = Tracer::ring(&TraceConfig::full());
    let m_off = profiled_run(3, &Prof::off(), &tr1);
    assert_eq!(to_jsonl(&sink1.unwrap().borrow().snapshot()), base_trace);
    assert_eq!(m_off, base_metrics);

    // Recording run: still the same bytes — observation only.
    let (prof, psink) = Prof::recording();
    let (tr2, sink2) = Tracer::ring(&TraceConfig::full());
    let m_on = profiled_run(3, &prof, &tr2);
    assert_eq!(to_jsonl(&sink2.unwrap().borrow().snapshot()), base_trace);
    assert_eq!(m_on, base_metrics);
    assert!(psink.borrow().clock() > 0, "recording run captured nothing");
}

#[test]
fn coserve_profiled_covers_arbiter_and_lane_phases_deterministically() {
    // The coserve_integration churn scenario (flux surge at t=0.5 forces a
    // re-arbitration), run twice with a recording profiler.
    let cluster = ClusterSpec::l20(6);
    let duration_ms = 240_000.0;
    let mut exports: Vec<(String, String)> = Vec::new();
    for _ in 0..2 {
        let sd3 = PipelineSetup::new("sd3", &cluster);
        let flux = PipelineSetup::new("flux", &cluster);
        let trace = {
            let specs = [
                MixedSpec {
                    pipeline: &sd3.pipeline,
                    profile: &sd3.profile,
                    kind: WorkloadKind::Medium,
                    rate_scale: 0.12,
                    load: LoadShape::Step { at: 0.5, before: 1.6, after: 0.3 },
                    difficulty: DifficultyModel::Uniform,
                },
                MixedSpec {
                    pipeline: &flux.pipeline,
                    profile: &flux.profile,
                    kind: WorkloadKind::Medium,
                    rate_scale: 0.15,
                    load: LoadShape::Step { at: 0.5, before: 0.3, after: 1.6 },
                    difficulty: DifficultyModel::Uniform,
                },
            ];
            mixed(&specs, duration_ms, 5)
        };
        let setups = vec![sd3, flux];
        let cfg = CoServeConfig {
            seed: 5,
            monitor_ms: 2_000.0,
            backlog_trigger_per_gpu: 0.1,
            resize: ResizePolicy::Preempt,
            ..Default::default()
        };
        let mut arb = ClusterArbiter::new(cluster.gpus_per_node);
        arb.cooldown_ms = 15_000.0;
        arb.trigger_streak = 1;
        let (prof, sink) = Prof::recording();
        let report = run_coserve_profiled(
            &setups,
            &cluster,
            &mut arb,
            &trace,
            &cfg,
            &Tracer::off(),
            &Telemetry::off(),
            &prof,
        );
        assert!(report.arbitrations >= 1, "scenario must force a re-arbitration");
        let sink = sink.borrow();
        exports.push((to_folded(&sink, Channel::Count), to_json(&sink, false)));
    }
    assert_eq!(exports[0].0, exports[1].0, "coserve folded stacks must be deterministic");
    assert_eq!(exports[0].1, exports[1].1, "coserve JSON export must be deterministic");
    let folded = &exports[0].0;
    // Arbiter solves are separated from dispatcher solves by ancestry.
    assert!(folded.contains("arbitrate"), "{folded}");
    assert!(folded.contains("tick;lane_tick;dispatch "), "{folded}");
    assert!(
        folded.contains("arbitrate;mckp_solve ") || folded.contains("arbitrate;mckp_seeded "),
        "arbiter MCKP must nest under arbitrate: {folded}"
    );
    // The applied re-arbitration shows up as handoff (+ checkpoint under
    // Preempt) accounting.
    assert!(folded.contains("handoff"), "{folded}");
}

#[test]
fn scale_sweep_schema_carries_exponents_and_gate_rejects_superlinear() {
    // A miniature in-process sweep: two scales, fitted exactly like
    // `benches/scale_sweep.rs` (same helpers, same naming).
    let mut sweep = Vec::new();
    for gpus in [16usize, 32] {
        let setup = Setup::new("flux", gpus);
        let (prof, sink) = Prof::recording();
        let _ = setup.run_scaled_profiled(
            "trident",
            WorkloadKind::Medium,
            10_000.0,
            0,
            1.0,
            &Tracer::off(),
            &Telemetry::off(),
            &prof,
        );
        sweep.push((gpus / 8, phase_totals(&sink.borrow())));
    }
    let mut out = BenchRecorder::new("scale_sweep");
    for phase in Phase::ALL {
        let series: Vec<(f64, f64)> = sweep
            .iter()
            .filter_map(|(nodes, totals)| {
                totals
                    .iter()
                    .find(|t| t.phase == phase)
                    .map(|t| (*nodes as f64, t.wall_self_ns as f64))
            })
            .collect();
        if series.len() == sweep.len() {
            out.record(&format!("{}_exponent", phase.name()), fit_loglog_exponent(&series));
        }
    }
    let baseline = format!("{}\n", out.to_json().to_string());
    assert!(
        baseline.contains("_exponent"),
        "sweep schema must carry per-phase exponents: {baseline}"
    );

    // Gate semantics through the same comparator `bench-check` runs in CI:
    // a phase whose fitted exponent jumps by 1.0 (linear gone quadratic)
    // fails; drift inside the band passes.
    let rows = |delta: f64| {
        let mut cur = BenchRecorder::new("scale_sweep");
        cur.record("free_view_exponent", 1.0 + delta);
        cur.record("dispatch_exponent", 0.2);
        format!("{}\n", cur.to_json().to_string())
    };
    let base = rows(0.0);
    let drifted = compare_benches(&base, &rows(0.2)).unwrap();
    assert!(!drifted.failed(), "{drifted}");
    let superlinear = compare_benches(&base, &rows(1.0)).unwrap();
    assert!(superlinear.failed(), "superlinear exponent growth must fail the gate");
    assert_eq!(superlinear.regressions().len(), 1);
}

#[test]
fn scopes_nest_and_survive_out_of_order_drops_at_depth() {
    let (prof, sink) = Prof::recording();
    {
        let _t = prof.scope(Phase::Tick);
        for _ in 0..3 {
            let _d = prof.scope(Phase::Dispatch);
            let _s = prof.scope(Phase::MckpSolve);
            // Recursive re-entry makes a child node, not a cycle.
            let _s2 = prof.scope(Phase::MckpSolve);
        }
        // Out-of-order drop: the outer guard closes the inner one.
        let outer = prof.scope(Phase::Advance);
        let inner = prof.scope(Phase::Handoff);
        drop(outer);
        drop(inner); // stale: must be a no-op
    }
    let sink = sink.borrow();
    assert_eq!(sink.open_depth(), 0, "all scopes must be closed");
    let folded = to_folded(&sink, Channel::Count);
    assert!(folded.contains("tick;dispatch;mckp_solve;mckp_solve 3"), "{folded}");
    assert!(folded.contains("tick;advance;handoff 1"), "{folded}");
    // Every enter is matched by exactly one exit in the logical clock.
    let entered: u64 = sink.nodes().iter().map(|n| n.count).sum();
    assert_eq!(sink.clock(), 2 * entered);
}
