//! Integration: whole-system simulations across policies and pipelines.
//!
//! These are behavioural (paper-shape) tests: TridentServe must beat the
//! static baseline, never OOM, and exercise placement switching under
//! dynamic load. Short traces keep runtime bounded.

use tridentserve::harness::Setup;
use tridentserve::request::Outcome;
use tridentserve::util::prop::run_prop;
use tridentserve::util::Rng;
use tridentserve::workload::WorkloadKind;

const THREE_MIN: f64 = 3.0 * 60_000.0;

#[test]
fn trident_never_ooms_anywhere() {
    for pipeline in ["flux", "hunyuan"] {
        let setup = Setup::new(pipeline, 128);
        for wk in [WorkloadKind::Heavy, WorkloadKind::Dynamic] {
            let m = setup.run("trident", wk, THREE_MIN, 1);
            assert_eq!(m.summary().oom, 0, "{pipeline}/{}", wk.label());
        }
    }
}

#[test]
fn b1_ooms_on_flux_but_not_sd3() {
    let flux = Setup::new("flux", 128);
    let m = flux.run("b1", WorkloadKind::Heavy, THREE_MIN, 1);
    assert!(m.summary().oom > 0, "B1 must OOM on heavy flux");

    let sd3 = Setup::new("sd3", 128);
    let m = sd3.run("b1", WorkloadKind::Light, 60_000.0, 1);
    assert_eq!(m.summary().oom, 0, "B1 must not OOM on sd3");
}

#[test]
fn trident_beats_b1_on_medium_flux() {
    let setup = Setup::new("flux", 128);
    let t = setup.run("trident", WorkloadKind::Medium, THREE_MIN, 2).summary();
    let b = setup.run("b1", WorkloadKind::Medium, THREE_MIN, 2).summary();
    assert!(
        t.slo_attainment >= b.slo_attainment,
        "trident {} < b1 {}",
        t.slo_attainment,
        b.slo_attainment
    );
}

#[test]
fn dynamic_workload_triggers_switches() {
    let setup = Setup::new("flux", 128);
    let m = setup.run("trident", WorkloadKind::Dynamic, 8.0 * 60_000.0, 3);
    assert!(
        !m.switch_events.is_empty(),
        "dynamic trace should trigger at least one placement switch"
    );
}

#[test]
fn woswitch_never_switches() {
    let setup = Setup::new("flux", 128);
    let m = setup.run("trident-woswitch", WorkloadKind::Dynamic, 5.0 * 60_000.0, 3);
    assert!(m.switch_events.is_empty());
}

#[test]
fn all_requests_accounted_for() {
    // Conservation: every arrival ends as exactly one completion record.
    let setup = Setup::new("cogvideo", 128);
    let tg = tridentserve::workload::TraceGen {
        pipeline: &setup.pipeline,
        profile: &setup.profile,
        rate_scale: 1.0,
        difficulty: tridentserve::workload::DifficultyModel::Uniform,
    };
    let trace = tg.generate(WorkloadKind::Medium, THREE_MIN, 4);
    let n_arrivals = trace.requests.len();
    let m = setup.run("trident", WorkloadKind::Medium, THREE_MIN, 4);
    assert_eq!(m.summary().n, n_arrivals, "requests lost or duplicated");
}

#[test]
fn latency_never_below_service_time() {
    let setup = Setup::new("flux", 128);
    let m = setup.run("trident", WorkloadKind::Light, THREE_MIN, 5);
    for c in &m.completions {
        if c.outcome == Outcome::Completed {
            let min_service = tridentserve::perfmodel::DEGREES
                .iter()
                .map(|&k| {
                    setup
                        .profile
                        .latency_ms(c.shape_idx, tridentserve::config::Stage::Diffuse, k)
                })
                .fold(f64::MAX, f64::min);
            assert!(
                c.latency_ms() > min_service * 0.5,
                "impossible latency {} for shape {}",
                c.latency_ms(),
                c.shape_idx
            );
        }
    }
}

#[test]
fn prop_sims_are_deterministic_per_seed() {
    run_prop(0x5EED, 3, |rng: &mut Rng, _| {
        let seed = rng.next_u64() % 1000;
        let setup = Setup::new("flux", 128);
        let a = setup.run("trident", WorkloadKind::Medium, 60_000.0, seed).summary();
        let b = setup.run("trident", WorkloadKind::Medium, 60_000.0, seed).summary();
        assert_eq!(a.n, b.n);
        assert!((a.slo_attainment - b.slo_attainment).abs() < 1e-12);
        assert!((a.mean_latency_ms - b.mean_latency_ms).abs() < 1e-9);
    });
}

#[test]
fn stage_level_baselines_survive_heavy_hunyuan() {
    let setup = Setup::new("hunyuan", 128);
    for p in ["b5", "b6"] {
        let m = setup.run(p, WorkloadKind::Heavy, THREE_MIN, 6);
        let s = m.summary();
        assert!(s.n > 0);
        // Disaggregated placements eliminate co-location OOMs (§8.2).
        assert_eq!(s.oom, 0, "{p} must not OOM");
    }
}
