//! Integration: lossless sequence parallelism over the AOT artifacts.
//!
//! The `attn_shard_r128_k{K}_s{S}` artifacts compute Ulysses head-shards of
//! the first DiT block's attention. Executing all K shards and summing
//! their outputs must reproduce the unsharded (k=1) result exactly (up to
//! fp addition order) — the numerical proof that degree-k dispatch plans
//! are lossless (§3 / DESIGN.md). Mirrors python/tests/test_shard_equivalence.py.

use std::path::PathBuf;

use tridentserve::runtime::PjrtRuntime;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn inputs() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = 256usize; // dit tokens at r128
    let pd = 8 * 2 * 2;
    let x: Vec<f32> = (0..n * pd).map(|i| ((i as f32) * 0.37).cos() * 0.5).collect();
    let cond: Vec<f32> = (0..16 * 64).map(|i| ((i as f32) * 0.11).sin()).collect();
    let t = vec![0.5f32];
    (x, cond, t)
}

#[test]
fn shard_sum_equals_unsharded() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::load(&dir, Some(&["attn_shard"])).unwrap();
    let (x, cond, t) = inputs();
    let x_dims = [1i64, 256, 32];
    let c_dims = [1i64, 16, 64];
    let t_dims = [1i64];

    let run = |name: &str| -> Vec<f32> {
        rt.run_f32(name, &[(&x, &x_dims), (&cond, &c_dims), (&t, &t_dims)])
            .unwrap()
            .0
    };

    let full = run("attn_shard_r128_k1_s0");
    assert!(full.iter().any(|&v| v.abs() > 1e-3), "degenerate full output");

    for degree in [2usize, 4] {
        let mut sum = vec![0f32; full.len()];
        for shard in 0..degree {
            let part = run(&format!("attn_shard_r128_k{degree}_s{shard}"));
            assert_eq!(part.len(), sum.len());
            for (acc, v) in sum.iter_mut().zip(&part) {
                *acc += v;
            }
        }
        let max_err = sum
            .iter()
            .zip(&full)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 2e-4, "degree {degree}: max err {max_err}");
    }
}

#[test]
fn shards_are_distinct() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = PjrtRuntime::load(&dir, Some(&["attn_shard_r128_k2"])).unwrap();
    let (x, cond, t) = inputs();
    let x_dims = [1i64, 256, 32];
    let run = |name: &str| -> Vec<f32> {
        rt.run_f32(name, &[(&x, &x_dims), (&cond, &[1, 16, 64]), (&t, &[1])])
            .unwrap()
            .0
    };
    let s0 = run("attn_shard_r128_k2_s0");
    let s1 = run("attn_shard_r128_k2_s1");
    let max_delta = s0.iter().zip(&s1).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    assert!(max_delta > 1e-4, "shards must compute different head groups");
}
