//! Telemetry integration (ISSUE 7 acceptance): the live-metrics subsystem
//! is pinned on four contracts, end-to-end over real runs —
//!
//! * **Non-perturbation** — attaching a registry changes nothing about the
//!   run it observes: completions, SLO attainment and (for the adaptive
//!   cascade) every threshold decision are identical to the unobserved run;
//! * **Coverage** — a co-serving run populates per-lane lifecycle counters
//!   that reconcile exactly with the metrics layer, per-lane gauge series,
//!   a mergeable latency histogram, and the monitor's stage-rate windows;
//! * **Exportability** — the Prometheus snapshot parses back line-by-line
//!   under the text-exposition grammar, and both exporters are
//!   byte-identical across same-seed runs;
//! * **Closed loop** — the adaptive cascade controller demonstrably reads
//!   its quality-verdict evidence from the shared registry window
//!   ([`metric::CASCADE_VERDICTS`]), not a private counter.

use std::collections::BTreeMap;

use tridentserve::cascade::{
    calibrate_threshold, run_cascade, run_cascade_observed, QualityModel, RouterMode,
    ThresholdController, VERDICT_CAP,
};
use tridentserve::config::ClusterSpec;
use tridentserve::coserve::{
    run_coserve, run_coserve_observed, ClusterArbiter, CoServeConfig, CoServeReport,
    PipelineSetup,
};
use tridentserve::obs::{Tracer, CONTROL_LANE};
use tridentserve::request::Outcome;
use tridentserve::telemetry::export::{to_csv, to_prometheus};
use tridentserve::telemetry::{metric, Telemetry};
use tridentserve::workload::{
    mixed, DifficultyModel, LoadShape, MixedSpec, MixedTrace, Trace, TraceGen, WorkloadKind,
};

const DURATION_MS: f64 = 120_000.0;

/// The opposed-step co-serving scenario from `tests/obs_trace.rs`: two
/// pipelines on one shared cluster, load shifting between them mid-run so
/// the arbiter (and therefore lane rebuilds) are exercised.
fn scenario(cluster: &ClusterSpec, seed: u64) -> (Vec<PipelineSetup>, MixedTrace) {
    let sd3 = PipelineSetup::new("sd3", cluster);
    let flux = PipelineSetup::new("flux", cluster);
    let trace = {
        let specs = [
            MixedSpec {
                pipeline: &sd3.pipeline,
                profile: &sd3.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.2,
                load: LoadShape::Step { at: 0.5, before: 1.4, after: 0.4 },
                difficulty: DifficultyModel::Uniform,
            },
            MixedSpec {
                pipeline: &flux.pipeline,
                profile: &flux.profile,
                kind: WorkloadKind::Medium,
                rate_scale: 0.2,
                load: LoadShape::Step { at: 0.5, before: 0.4, after: 1.4 },
                difficulty: DifficultyModel::Uniform,
            },
        ];
        mixed(&specs, DURATION_MS, seed)
    };
    (vec![sd3, flux], trace)
}

fn arbiter(cluster: &ClusterSpec, cooldown_ms: f64) -> ClusterArbiter {
    let mut a = ClusterArbiter::new(cluster.gpus_per_node);
    a.cooldown_ms = cooldown_ms;
    a.trigger_streak = 1;
    a
}

fn lane_completed(report: &CoServeReport, p: usize) -> usize {
    report.lanes[p]
        .metrics
        .completions
        .iter()
        .filter(|c| c.outcome == Outcome::Completed)
        .count()
}

fn completed(report: &CoServeReport) -> usize {
    (0..report.lanes.len()).map(|p| lane_completed(report, p)).sum()
}

/// Run the scenario with a live registry attached; tracing stays off so
/// the only observer under test is telemetry.
fn observed_run(seed: u64) -> (CoServeReport, std::rc::Rc<std::cell::RefCell<tridentserve::telemetry::Registry>>) {
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = scenario(&cluster, seed);
    let cfg = CoServeConfig { seed, ..Default::default() };
    let (tele, reg) = Telemetry::registry();
    let mut arb = arbiter(&cluster, 20_000.0);
    let report =
        run_coserve_observed(&setups, &cluster, &mut arb, &trace, &cfg, &Tracer::off(), &tele);
    (report, reg)
}

#[test]
fn observed_coserve_populates_the_registry_without_perturbing_the_run() {
    let cluster = ClusterSpec::l20(4);
    let (setups, trace) = scenario(&cluster, 3);
    let cfg = CoServeConfig { seed: 3, ..Default::default() };

    let mut arb = arbiter(&cluster, 20_000.0);
    let plain = run_coserve(&setups, &cluster, &mut arb, &trace, &cfg);
    let (observed, reg) = observed_run(3);

    // Observing the run must not change it.
    assert_eq!(completed(&plain), completed(&observed), "telemetry perturbed completions");
    for (p, (a, b)) in plain.lanes.iter().zip(observed.lanes.iter()).enumerate() {
        assert_eq!(a.metrics.summary().n, b.metrics.summary().n, "lane {p} diverged");
        assert_eq!(
            a.metrics.summary().slo_attainment,
            b.metrics.summary().slo_attainment,
            "lane {p} SLO attainment diverged"
        );
    }

    // Per-lane lifecycle counters reconcile exactly with the metrics layer,
    // and the monitor-cadence gauges produced real series.
    {
        let reg = reg.borrow();
        for p in 0..observed.lanes.len() {
            let lane = p as u32;
            let arrived = reg.counter(metric::REQUESTS_ARRIVED, lane).unwrap_or(0);
            assert!(arrived > 0, "lane {p} never counted an arrival");
            assert_eq!(
                reg.counter(metric::REQUESTS_COMPLETED, lane).unwrap_or(0),
                lane_completed(&observed, p) as u64,
                "lane {p} completion counter out of step with metrics"
            );
            for name in [metric::QUEUE_DEPTH, metric::GPU_UTILIZATION, metric::HANDOFF_GB] {
                assert!(
                    reg.series_of(name, lane).is_some_and(|s| !s.is_empty()),
                    "lane {p} has no {name} series"
                );
            }
        }
        // The cluster-wide latency roll-up is an associative merge across
        // lanes and must count every completion exactly once.
        let merged = reg.merged_hist(metric::REQUEST_LATENCY_MS).expect("latency histogram");
        assert_eq!(
            merged.count(),
            completed(&observed) as u64,
            "merged latency histogram lost completions"
        );
    }

    // The monitor's stage-rate windows were re-homed into the registry
    // (observe→decide loop): the window the §5.3 trigger reads is the one
    // we can see here, and a real run left evidence in it.
    let handle = Telemetry::with_registry(reg.clone());
    let diffuse = handle
        .for_lane(0)
        .shared_window(metric::STAGE_RATE[1], 60_000.0)
        .expect("registry handle always returns a window");
    assert!(
        !diffuse.borrow().is_empty(),
        "lane 0 monitor never recorded a diffuse completion in the shared window"
    );
}

/// Line-by-line parse-back of the Prometheus text exposition: every sample
/// belongs to a declared family, values are finite floats, label syntax is
/// well-formed, counters are integral and `_total`-suffixed, and native
/// histograms are cumulative in `le` order with `+Inf` equal to `_count`.
fn assert_prometheus_conformant(text: &str) {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    // (family base, lane label) → cumulative buckets in order of appearance.
    let mut buckets: BTreeMap<(String, String), Vec<(f64, f64)>> = BTreeMap::new();
    let mut hist_counts: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut samples = 0usize;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or_else(|| panic!("TYPE without a name: {line}"));
            let ty = it.next().unwrap_or_else(|| panic!("TYPE without a type: {line}"));
            assert!(it.next().is_none(), "trailing tokens on TYPE line: {line}");
            assert!(
                matches!(ty, "counter" | "gauge" | "summary" | "histogram"),
                "unknown metric type {ty}: {line}"
            );
            assert!(
                types.insert(name.to_string(), ty.to_string()).is_none(),
                "duplicate TYPE declaration for {name}"
            );
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            assert!(rest.split_whitespace().count() >= 2, "HELP without text: {line}");
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment form: {line}");

        let (head, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("sample without a value: {line}"));
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("unparsable value: {line}"));
        assert!(v.is_finite(), "non-finite sample value: {line}");

        let mut le: Option<f64> = None;
        let mut lane_label = String::new();
        let name = match head.split_once('{') {
            Some((n, labels)) => {
                let labels =
                    labels.strip_suffix('}').unwrap_or_else(|| panic!("unclosed labels: {line}"));
                for kv in labels.split(',') {
                    let (k, val) = kv
                        .split_once("=\"")
                        .unwrap_or_else(|| panic!("malformed label {kv}: {line}"));
                    assert!(val.ends_with('"'), "unterminated label value: {line}");
                    assert!(
                        matches!(k, "lane" | "quantile" | "le"),
                        "unexpected label key {k}: {line}"
                    );
                    let val = val.trim_end_matches('"');
                    match k {
                        // "+Inf" parses to f64::INFINITY, which is exactly
                        // what the cumulative check needs.
                        "le" => {
                            le = Some(val.parse().unwrap_or_else(|_| {
                                panic!("unparsable le bound: {line}")
                            }))
                        }
                        "lane" => lane_label = val.to_string(),
                        _ => {}
                    }
                }
                n
            }
            None => head,
        };
        assert!(name.starts_with("trident_"), "sample without exposition prefix: {line}");
        assert!(
            name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "illegal character in metric name: {line}"
        );

        // Family resolution: exact name (counter / gauge / summary quantile
        // line) or the base name for a summary's/histogram's `_sum`/
        // `_count`/`_bucket` samples.
        let family_ty = types
            .get(name)
            .cloned()
            .or_else(|| {
                name.strip_suffix("_sum")
                    .or_else(|| name.strip_suffix("_count"))
                    .or_else(|| name.strip_suffix("_bucket"))
                    .and_then(|base| types.get(base).cloned())
            })
            .unwrap_or_else(|| panic!("sample {name} has no TYPE declaration"));
        if family_ty == "counter" {
            assert!(name.ends_with("_total"), "counter without _total suffix: {line}");
            assert!(
                v >= 0.0 && v.fract() == 0.0,
                "counter must be a non-negative integer: {line}"
            );
        }
        if family_ty == "histogram" {
            if let Some(base) = name.strip_suffix("_bucket") {
                let bound =
                    le.unwrap_or_else(|| panic!("histogram bucket without le label: {line}"));
                buckets
                    .entry((base.to_string(), lane_label.clone()))
                    .or_default()
                    .push((bound, v));
            } else if let Some(base) = name.strip_suffix("_count") {
                hist_counts.insert((base.to_string(), lane_label.clone()), v);
            }
        } else {
            assert!(le.is_none(), "le label outside a histogram family: {line}");
        }
        samples += 1;
    }
    assert!(samples > 0, "empty exposition");
    // Histogram semantics: per series, bounds strictly increase, counts
    // are cumulative (non-decreasing), and the mandatory `+Inf` bucket
    // closes the series at exactly `_count`.
    assert!(!buckets.is_empty(), "a real run must expose native histogram buckets");
    for ((base, lane), series) in &buckets {
        for pair in series.windows(2) {
            assert!(pair[0].0 < pair[1].0, "{base} lane {lane:?}: le bounds out of order");
            assert!(pair[0].1 <= pair[1].1, "{base} lane {lane:?}: buckets not cumulative");
        }
        let &(last_bound, last_cum) = series.last().unwrap();
        assert!(last_bound.is_infinite(), "{base} lane {lane:?}: missing +Inf bucket");
        let total = hist_counts
            .get(&(base.clone(), lane.clone()))
            .unwrap_or_else(|| panic!("{base} lane {lane:?}: buckets without _count"));
        assert_eq!(last_cum, *total, "{base} lane {lane:?}: +Inf bucket != _count");
    }
    for want in ["counter", "gauge", "summary", "histogram"] {
        assert!(
            types.values().any(|t| t == want),
            "a real run must expose at least one {want}"
        );
    }
}

#[test]
fn prometheus_snapshot_from_a_real_run_parses_back() {
    let (_, reg) = observed_run(5);
    let text = to_prometheus(&reg.borrow());
    assert_prometheus_conformant(&text);
    // Spot-check the families this PR's samplers are responsible for.
    for needle in [
        "# TYPE trident_requests_arrived_total counter",
        "# TYPE trident_queue_depth gauge",
        "# TYPE trident_request_latency_ms summary",
        "# TYPE trident_request_latency_ms_hist histogram",
        "trident_request_latency_ms_hist_bucket{le=\"+Inf\"}",
    ] {
        assert!(text.contains(needle), "missing {needle}");
    }
}

#[test]
fn same_seed_observed_runs_export_byte_identically() {
    let (ra, rega) = observed_run(9);
    let (rb, regb) = observed_run(9);
    assert_eq!(completed(&ra), completed(&rb));

    let (rega, regb) = (rega.borrow(), regb.borrow());
    let (prom_a, prom_b) = (to_prometheus(&rega), to_prometheus(&regb));
    let (csv_a, csv_b) = (to_csv(&rega), to_csv(&regb));
    assert_eq!(prom_a, prom_b, "same seed must expose byte-identical Prometheus text");
    assert_eq!(csv_a, csv_b, "same seed must export byte-identical CSV");

    // CSV well-formedness + global sort order: header then
    // (t_ms, lane, metric)-ordered rows, every field parsable.
    let mut lines = csv_a.lines();
    assert_eq!(lines.next(), Some("t_ms,lane,metric,value"));
    let mut prev: Option<(f64, i64, String)> = None;
    let mut rows = 0usize;
    for line in lines {
        let f: Vec<&str> = line.split(',').collect();
        assert_eq!(f.len(), 4, "malformed CSV row: {line}");
        let t: f64 = f[0].parse().unwrap_or_else(|_| panic!("bad t_ms: {line}"));
        let lane: i64 = f[1].parse().unwrap_or_else(|_| panic!("bad lane: {line}"));
        let _: f64 = f[3].parse().unwrap_or_else(|_| panic!("bad value: {line}"));
        assert!(!f[2].is_empty(), "empty metric name: {line}");
        let key = (t, lane, f[2].to_string());
        if let Some(p) = &prev {
            assert!(
                p.0 < key.0 || (p.0 == key.0 && (p.1, &p.2) <= (key.1, &key.2)),
                "CSV rows out of order at: {line}"
            );
        }
        prev = Some(key);
        rows += 1;
    }
    assert!(rows > 0, "a real run must produce series rows");
}

/// ISSUE 7 acceptance: at least one controller demonstrably consumes a
/// telemetry rolling-window signal. The adaptive cascade controller's
/// quality-verdict evidence is re-homed into the registry's
/// [`metric::CASCADE_VERDICTS`] window before the run — so the threshold
/// decisions it makes are decisions *read out of telemetry* — and the
/// rewiring must not change a single one of them.
#[test]
fn adaptive_cascade_controller_consumes_the_registry_verdict_window() {
    const CASCADE_DURATION_MS: f64 = 240_000.0;
    let cluster = ClusterSpec::l20(4);
    let cheap = PipelineSetup::new("sd3-turbo", &cluster);
    let heavy = PipelineSetup::new("sd3", &cluster);
    let drift = DifficultyModel::Drift { from: 0.2, to: 0.55 };
    let trace: Trace = {
        let mut tg = TraceGen::new(&heavy.pipeline, &heavy.profile);
        tg.rate_scale = 0.15;
        tg.difficulty = drift;
        tg.steady(WorkloadKind::Medium, CASCADE_DURATION_MS, 11)
    };
    let quality = QualityModel { adequacy_cut: 0.55, conf_noise: 0.10 };
    let floor = 0.92;
    let tau0 = calibrate_threshold(&quality, &drift, 0.0, floor, 11);
    let mode = || RouterMode::Adaptive {
        initial_threshold: tau0,
        controller: ThresholdController::new(floor),
    };
    let cfg = CoServeConfig { seed: 11, monitor_ms: 2_000.0, ..Default::default() };

    let mut arb = arbiter(&cluster, 30_000.0);
    let plain = run_cascade(&cheap, &heavy, &cluster, &mut arb, &trace, mode(), quality, &cfg);

    let (tele, reg) = Telemetry::registry();
    let mut arb = arbiter(&cluster, 30_000.0);
    let observed = run_cascade_observed(
        &cheap,
        &heavy,
        &cluster,
        &mut arb,
        &trace,
        mode(),
        quality,
        &cfg,
        &Tracer::off(),
        &tele,
    );

    // Every decision identical: same threshold walk, same escalation set.
    assert_eq!(
        plain.threshold_trace, observed.threshold_trace,
        "registry-backed verdict window changed the controller's decisions"
    );
    assert_eq!(plain.final_threshold, observed.final_threshold);
    assert_eq!(plain.escalated, observed.escalated);
    assert!(observed.escalations() > 0, "drift never forced an escalation — nothing exercised");

    // The evidence the controller acted on lives in the shared registry
    // window, and the control-lane series/counters reflect the loop.
    let ctl = Telemetry::with_registry(reg.clone()).for_lane(CONTROL_LANE);
    let verdicts = ctl
        .shared_verdicts(metric::CASCADE_VERDICTS, VERDICT_CAP)
        .expect("registry handle always returns a window");
    assert!(
        verdicts.borrow().observed() > 0,
        "controller verdicts never landed in the registry window"
    );
    let reg = reg.borrow();
    assert_eq!(
        reg.counter(metric::CASCADE_ESCALATIONS, CONTROL_LANE).unwrap_or(0),
        observed.escalations() as u64,
        "escalation counter out of step with the report"
    );
    for name in [metric::CASCADE_QUALITY, metric::CASCADE_ESCALATION_RATE] {
        assert!(
            reg.series_of(name, CONTROL_LANE).is_some_and(|s| !s.is_empty()),
            "control lane has no {name} series"
        );
    }
}
